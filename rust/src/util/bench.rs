//! Criterion-style micro/macro benchmark harness (criterion itself is not
//! available in the offline vendor set). Provides warmup, adaptive
//! iteration counts, and mean/median/stddev reporting. `cargo bench`
//! targets use `harness = false` and drive this directly.

use std::time::{Duration, Instant};

use super::stats;

pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
    /// Optional throughput denominator (e.g. simulated cycles per call)
    /// set via `Bencher::throughput`.
    pub throughput: Option<(f64, &'static str)>,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let mut line = format!(
            "{:<44} {:>12}/iter  median {:>12}  sd {:>10}  ({} iters)",
            self.name,
            stats::fmt_ns(self.mean_ns),
            stats::fmt_ns(self.median_ns),
            stats::fmt_ns(self.stddev_ns),
            self.iters,
        );
        if let Some((units, label)) = self.throughput {
            let per_sec = units / (self.mean_ns / 1e9);
            line.push_str(&format!("  [{} {label}/s]", stats::si(per_sec)));
        }
        line
    }
}

pub struct Bench {
    /// Minimum measurement time per benchmark.
    pub measure_time: Duration,
    pub warmup_time: Duration,
    /// Cap on total iterations (protects multi-second macro benches).
    pub max_iters: u64,
    pub results: Vec<BenchResult>,
    /// Destination for the JSON artifact (`--save-json <path>`).
    pub json_path: Option<String>,
    filter: Option<String>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            measure_time: Duration::from_millis(700),
            warmup_time: Duration::from_millis(200),
            max_iters: 100_000_000,
            results: Vec::new(),
            json_path: None,
            filter: None,
        }
    }
}

impl Bench {
    /// Standard constructor honoring a `--bench <filter>`-style argv filter
    /// (cargo bench passes the filter as a bare positional), `--quick`,
    /// and `--save-json <path>` (machine-readable results for the
    /// perf-trajectory artifact — see `BENCH_sim_hotpath.json`).
    pub fn from_env() -> Self {
        let mut b = Bench::default();
        let args: Vec<String> = std::env::args().skip(1).collect();
        let mut i = 0;
        while i < args.len() {
            match args[i].as_str() {
                "--quick" => {
                    b.measure_time = Duration::from_millis(120);
                    b.warmup_time = Duration::from_millis(30);
                }
                "--save-json" => match args.get(i + 1) {
                    Some(path) if !path.starts_with('-') => {
                        b.json_path = Some(path.clone());
                        i += 1;
                    }
                    _ => panic!("--save-json requires a path argument"),
                },
                // cargo bench passes `--bench`; any other non-flag
                // positional is a name filter.
                a if !a.starts_with('-') && b.filter.is_none() => {
                    b.filter = Some(a.to_string());
                }
                _ => {}
            }
            i += 1;
        }
        b
    }

    /// Serialize all results to the machine-readable artifact format.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::{obj, Json};
        let results: Vec<Json> = self
            .results
            .iter()
            .map(|r| {
                let mut fields = vec![
                    ("name", Json::Str(r.name.clone())),
                    ("iters", Json::Int(r.iters as i64)),
                    ("mean_ns", Json::Float(r.mean_ns)),
                    ("median_ns", Json::Float(r.median_ns)),
                    ("stddev_ns", Json::Float(r.stddev_ns)),
                    ("min_ns", Json::Float(r.min_ns)),
                    ("max_ns", Json::Float(r.max_ns)),
                ];
                if let Some((units, label)) = &r.throughput {
                    fields.push(("throughput_units", Json::Float(*units)));
                    fields.push(("throughput_label", Json::Str(label.to_string())));
                    fields.push(("per_sec", Json::Float(*units / (r.mean_ns / 1e9))));
                }
                obj(fields)
            })
            .collect();
        obj([("results", Json::Array(results))])
    }

    /// Write the JSON artifact if `--save-json <path>` was requested.
    /// Bench mains call this once after their last benchmark.
    pub fn save_if_requested(&self) {
        if let Some(path) = &self.json_path {
            crate::util::fsx::atomic_write(
                std::path::Path::new(path),
                self.to_json().to_string_pretty().as_bytes(),
            )
            .unwrap_or_else(|e| panic!("writing bench json {path}: {e}"));
            println!("bench results written to {path}");
        }
    }

    fn matches(&self, name: &str) -> bool {
        match &self.filter {
            Some(f) => name.contains(f.as_str()),
            None => true,
        }
    }

    /// Benchmark a closure. The closure's return value is black-boxed to
    /// keep the optimizer honest.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, f: F) {
        self.bench_throughput(name, None, f)
    }

    /// Benchmark with a throughput annotation, e.g.
    /// `(cycles_per_call as f64, "sim-cycles")`.
    pub fn bench_throughput<T, F: FnMut() -> T>(
        &mut self,
        name: &str,
        throughput: Option<(f64, &'static str)>,
        mut f: F,
    ) {
        if !self.matches(name) {
            return;
        }
        // Warmup and calibration: how many iters fit in the warmup window?
        let start = Instant::now();
        let mut warm_iters = 0u64;
        while start.elapsed() < self.warmup_time || warm_iters == 0 {
            black_box(f());
            warm_iters += 1;
            if warm_iters >= self.max_iters {
                break;
            }
        }
        let per_iter = self.warmup_time.as_secs_f64() / warm_iters as f64;
        // Target ~30 samples of batched iterations within measure_time.
        let samples = 30u64;
        let batch = ((self.measure_time.as_secs_f64() / samples as f64 / per_iter).ceil()
            as u64)
            .clamp(1, self.max_iters);

        let mut sample_ns: Vec<f64> = Vec::with_capacity(samples as usize);
        let mut total_iters = 0u64;
        let measure_start = Instant::now();
        for _ in 0..samples {
            let t = Instant::now();
            for _ in 0..batch {
                black_box(f());
            }
            sample_ns.push(t.elapsed().as_nanos() as f64 / batch as f64);
            total_iters += batch;
            if measure_start.elapsed() > self.measure_time * 4 {
                break; // macro bench taking too long; stop early
            }
        }
        let result = BenchResult {
            name: name.to_string(),
            iters: total_iters,
            mean_ns: stats::mean(&sample_ns),
            median_ns: stats::median(&sample_ns),
            stddev_ns: stats::stddev(&sample_ns),
            min_ns: sample_ns.iter().cloned().fold(f64::INFINITY, f64::min),
            max_ns: sample_ns.iter().cloned().fold(0.0, f64::max),
            throughput,
        };
        println!("{}", result.report());
        self.results.push(result);
    }

    /// Run a one-shot macro measurement (no repetition) for multi-second
    /// end-to-end runs where repetition is impractical.
    pub fn once<T, F: FnOnce() -> T>(&mut self, name: &str, f: F) -> Option<T> {
        if !self.matches(name) {
            return None;
        }
        let t = Instant::now();
        let out = black_box(f());
        let ns = t.elapsed().as_nanos() as f64;
        let result = BenchResult {
            name: name.to_string(),
            iters: 1,
            mean_ns: ns,
            median_ns: ns,
            stddev_ns: 0.0,
            min_ns: ns,
            max_ns: ns,
            throughput: None,
        };
        println!("{}", result.report());
        self.results.push(result);
        Some(out)
    }
}

/// Optimization barrier (stable-Rust `std::hint::black_box`).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bench {
            measure_time: Duration::from_millis(20),
            warmup_time: Duration::from_millis(5),
            ..Bench::default()
        };
        let mut acc = 0u64;
        b.bench("noop-sum", || {
            acc = acc.wrapping_add(1);
            acc
        });
        assert_eq!(b.results.len(), 1);
        assert!(b.results[0].mean_ns > 0.0);
        assert!(b.results[0].iters > 0);
    }

    #[test]
    fn filter_skips() {
        let mut b = Bench::default();
        b.filter = Some("match-me".into());
        b.bench("other", || 1);
        assert!(b.results.is_empty());
    }

    #[test]
    fn json_artifact_contains_results() {
        let mut b = Bench {
            measure_time: Duration::from_millis(10),
            warmup_time: Duration::from_millis(2),
            ..Bench::default()
        };
        b.bench_throughput("probe", Some((100.0, "ops")), || 1);
        let j = b.to_json();
        let arr = match j.get("results") {
            Some(crate::util::json::Json::Array(a)) => a,
            other => panic!("expected results array, got {other:?}"),
        };
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("name").unwrap().as_str().unwrap(), "probe");
        assert!(arr[0].get("per_sec").is_some(), "throughput probes record per_sec");
    }

    #[test]
    fn once_returns_value() {
        let mut b = Bench::default();
        let v = b.once("one-shot", || 42);
        assert_eq!(v, Some(42));
        assert_eq!(b.results[0].iters, 1);
    }
}
