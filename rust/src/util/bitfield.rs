//! Bit-packing helpers for the variable-width ISA encoding (§II-B).
//!
//! VTA instructions are a fixed 128 bits with *configuration-dependent*
//! field widths; uops are a configurable multiple of 8 bits. `BitWriter`
//! and `BitReader` pack/unpack little-endian bit streams over `u128`,
//! which covers both.

/// Sequential little-endian bit writer into a `u128`.
#[derive(Debug, Default, Clone)]
pub struct BitWriter {
    value: u128,
    pos: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Append `width` bits of `v` (must fit). Panics on overflow of the
    /// value or the 128-bit budget — these are *compiler* bugs, matching
    /// the paper's "compile-time checks ... need to be implemented".
    pub fn push(&mut self, v: u64, width: u32) -> &mut Self {
        assert!(width <= 64, "field width {width} > 64");
        assert!(
            width == 64 || v < (1u64 << width),
            "value {v} does not fit in {width} bits"
        );
        assert!(
            self.pos + width <= 128,
            "instruction overflows 128 bits at bit {}",
            self.pos
        );
        self.value |= (v as u128) << self.pos;
        self.pos += width;
        self
    }

    /// Append a signed value in two's complement over `width` bits.
    pub fn push_signed(&mut self, v: i64, width: u32) -> &mut Self {
        assert!(width >= 1 && width <= 64);
        let lo = -(1i64 << (width - 1));
        let hi = (1i64 << (width - 1)) - 1;
        assert!(v >= lo && v <= hi, "signed value {v} does not fit in {width} bits");
        let mask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
        self.push((v as u64) & mask, width)
    }

    pub fn bits_used(&self) -> u32 {
        self.pos
    }

    pub fn finish(&self) -> u128 {
        self.value
    }
}

/// Sequential little-endian bit reader from a `u128`.
#[derive(Debug, Clone)]
pub struct BitReader {
    value: u128,
    pos: u32,
}

impl BitReader {
    pub fn new(value: u128) -> Self {
        BitReader { value, pos: 0 }
    }

    pub fn pull(&mut self, width: u32) -> u64 {
        assert!(width <= 64);
        assert!(self.pos + width <= 128, "read past 128 bits");
        let mask: u128 = if width == 128 { u128::MAX } else { (1u128 << width) - 1 };
        let v = ((self.value >> self.pos) & mask) as u64;
        self.pos += width;
        v
    }

    pub fn pull_signed(&mut self, width: u32) -> i64 {
        let raw = self.pull(width);
        let sign_bit = 1u64 << (width - 1);
        if raw & sign_bit != 0 {
            (raw as i64) - (1i64 << width)
        } else {
            raw as i64
        }
    }

    pub fn bits_read(&self) -> u32 {
        self.pos
    }
}

/// Number of bits needed to address `n` distinct values (`ceil(log2 n)`,
/// minimum 1). This is how scratchpad depths become ISA field widths.
pub fn addr_bits(n: u64) -> u32 {
    if n <= 1 {
        1
    } else {
        64 - (n - 1).leading_zeros()
    }
}

/// `ceil(log2 n)` for sizes (0 for n<=1).
pub fn clog2(n: u64) -> u32 {
    if n <= 1 {
        0
    } else {
        64 - (n - 1).leading_zeros()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_fields() {
        let mut w = BitWriter::new();
        w.push(0b101, 3).push(0xff, 8).push(1, 1).push(12345, 20);
        let mut r = BitReader::new(w.finish());
        assert_eq!(r.pull(3), 0b101);
        assert_eq!(r.pull(8), 0xff);
        assert_eq!(r.pull(1), 1);
        assert_eq!(r.pull(20), 12345);
    }

    #[test]
    fn signed_roundtrip() {
        for v in [-8i64, -1, 0, 1, 7] {
            let mut w = BitWriter::new();
            w.push_signed(v, 4);
            let mut r = BitReader::new(w.finish());
            assert_eq!(r.pull_signed(4), v, "width 4 value {v}");
        }
        let mut w = BitWriter::new();
        w.push_signed(-32768, 16).push_signed(32767, 16);
        let mut r = BitReader::new(w.finish());
        assert_eq!(r.pull_signed(16), -32768);
        assert_eq!(r.pull_signed(16), 32767);
    }

    #[test]
    #[should_panic(expected = "does not fit")]
    fn overflow_value_panics() {
        BitWriter::new().push(16, 4);
    }

    #[test]
    #[should_panic(expected = "overflows 128 bits")]
    fn overflow_budget_panics() {
        let mut w = BitWriter::new();
        w.push(0, 64).push(0, 64).push(1, 1);
    }

    #[test]
    fn full_128_bits_ok() {
        let mut w = BitWriter::new();
        w.push(u64::MAX, 64).push(u64::MAX, 64);
        assert_eq!(w.bits_used(), 128);
        let mut r = BitReader::new(w.finish());
        assert_eq!(r.pull(64), u64::MAX);
        assert_eq!(r.pull(64), u64::MAX);
    }

    #[test]
    fn addr_bits_values() {
        assert_eq!(addr_bits(1), 1);
        assert_eq!(addr_bits(2), 1);
        assert_eq!(addr_bits(3), 2);
        assert_eq!(addr_bits(1024), 10);
        assert_eq!(addr_bits(1025), 11);
    }

    #[test]
    fn clog2_values() {
        assert_eq!(clog2(1), 0);
        assert_eq!(clog2(2), 1);
        assert_eq!(clog2(16), 4);
        assert_eq!(clog2(17), 5);
    }
}
