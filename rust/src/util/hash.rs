//! Stable FNV-1a (64-bit) hashing.
//!
//! Used for exec-state buffer digests (trace-based validation) and the
//! sweep engine's result-cache keys. `std::hash` is explicitly not
//! stable across processes or releases; FNV-1a is, and is plenty for
//! our own canonical strings and buffer contents (no DoS exposure).

pub struct Fnv(u64);

impl Fnv {
    pub fn new() -> Fnv {
        Fnv(0xcbf29ce484222325)
    }

    pub fn write_u8(&mut self, b: u8) {
        self.0 ^= b as u64;
        self.0 = self.0.wrapping_mul(0x100000001b3);
    }

    pub fn write_u32(&mut self, v: u32) {
        for b in v.to_le_bytes() {
            self.write_u8(b);
        }
    }

    pub fn write_u64(&mut self, v: u64) {
        for b in v.to_le_bytes() {
            self.write_u8(b);
        }
    }

    pub fn write_bool(&mut self, v: bool) {
        self.write_u8(v as u8);
    }

    pub fn write_i8s(&mut self, vs: &[i8]) {
        for &v in vs {
            self.write_u8(v as u8);
        }
    }

    pub fn write_str(&mut self, s: &str) {
        for &b in s.as_bytes() {
            self.write_u8(b);
        }
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv {
    fn default() -> Fnv {
        Fnv::new()
    }
}

/// One-shot FNV-1a of a string (the sweep cache-key hash).
pub fn fnv1a64(s: &str) -> u64 {
    let mut h = Fnv::new();
    h.write_str(s);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matches_published_fnv1a_vectors() {
        assert_eq!(fnv1a64(""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64("a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64("foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn wide_writers_agree_with_bytes() {
        let mut a = Fnv::new();
        a.write_u64(0x0102030405060708);
        a.write_bool(true);
        let mut b = Fnv::new();
        for byte in 0x0102030405060708u64.to_le_bytes() {
            b.write_u8(byte);
        }
        b.write_u8(1);
        assert_eq!(a.finish(), b.finish());
    }

    #[test]
    fn byte_writers_agree_with_str() {
        let mut h = Fnv::new();
        h.write_u8(b'a');
        assert_eq!(h.finish(), fnv1a64("a"));
    }
}
