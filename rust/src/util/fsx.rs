//! Crash-safe filesystem helpers.
//!
//! Every whole-file JSON artifact the stack writes (sweep summaries,
//! serve reports, configs, bench baselines, the artifact-store manifest)
//! goes through [`atomic_write`]: the bytes land in a same-directory
//! temporary file which is then renamed over the target. `rename(2)` is
//! atomic on every platform we run on, so a reader — including a resumed
//! run after a kill — observes either the old file or the complete new
//! one, never a truncated half-write.
//!
//! Append-only JSONL streams (the sweep result cache, the layer-memo
//! spill, the artifact store's kind files) deliberately do **not** use
//! this helper: rewriting the whole file per record would be O(n²), and
//! their loaders are already truncation-tolerant (a torn tail line is
//! counted and skipped, and the point simply re-evaluates). The atomic
//! path covers the files whose loaders are *not* line-oriented.

use std::fs::{self, File};
use std::io::{self, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// Distinguishes concurrent writers within one process; the pid in the
/// temp name distinguishes processes sharing a directory.
static TEMP_SEQ: AtomicU64 = AtomicU64::new(0);

fn temp_sibling(path: &Path) -> PathBuf {
    let name = path
        .file_name()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "file".to_string());
    let seq = TEMP_SEQ.fetch_add(1, Ordering::Relaxed);
    path.with_file_name(format!(".{name}.tmp.{}.{seq}", std::process::id()))
}

/// Write `bytes` to `path` atomically: temp file in the same directory
/// (rename across filesystems is not atomic), flush, then rename over
/// the target. On any error the temp file is removed and the target is
/// untouched.
pub fn atomic_write(path: &Path, bytes: &[u8]) -> io::Result<()> {
    let tmp = temp_sibling(path);
    let result = (|| {
        let mut file = File::create(&tmp)?;
        file.write_all(bytes)?;
        file.flush()?;
        file.sync_all()?;
        fs::rename(&tmp, path)
    })();
    if result.is_err() {
        fs::remove_file(&tmp).ok();
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_dir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("vta_fsx_{}_{name}", std::process::id()));
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn writes_and_overwrites() {
        let dir = temp_dir("roundtrip");
        let path = dir.join("out.json");
        atomic_write(&path, b"first").unwrap();
        assert_eq!(fs::read(&path).unwrap(), b"first");
        atomic_write(&path, b"second, longer payload").unwrap();
        assert_eq!(fs::read(&path).unwrap(), b"second, longer payload");
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn leaves_no_temp_files_behind() {
        let dir = temp_dir("clean");
        let path = dir.join("out.json");
        atomic_write(&path, b"payload").unwrap();
        let names: Vec<String> = fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        assert_eq!(names, vec!["out.json".to_string()], "only the target may remain");
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn failed_write_preserves_target() {
        let dir = temp_dir("preserve");
        let path = dir.join("out.json");
        atomic_write(&path, b"good").unwrap();
        // Writing *at* a directory path must fail without touching the
        // sibling target or leaving temp droppings.
        let bad = dir.join("sub");
        fs::create_dir_all(bad.join("x")).unwrap();
        assert!(atomic_write(&bad.join("x"), b"nope").is_err());
        assert_eq!(fs::read(&path).unwrap(), b"good");
        fs::remove_dir_all(&dir).ok();
    }
}
