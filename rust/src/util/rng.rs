//! Deterministic PRNG (PCG32) used everywhere randomness is needed:
//! synthetic int8 weights/activations, property-test case generation, and
//! workload sampling. Seeded explicitly so every experiment in
//! EXPERIMENTS.md is exactly reproducible.

/// PCG-XSH-RR 64/32 — small, fast, and statistically solid for test-data
/// generation. Not cryptographic (doesn't need to be).
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Convenience constructor with the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6364136223846793005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift with rejection.
    pub fn next_below(&mut self, bound: u32) -> u32 {
        assert!(bound > 0, "next_below(0)");
        let threshold = bound.wrapping_neg() % bound;
        loop {
            let r = self.next_u32();
            let m = (r as u64) * (bound as u64);
            if (m as u32) >= threshold {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform in the inclusive range `[lo, hi]`.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        if span == 0 {
            // full u64 range
            return self.next_u64() as i64;
        }
        lo + (self.next_u64() % span) as i64
    }

    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        self.range_i64(lo as i64, hi as i64) as usize
    }

    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    pub fn bool(&mut self) -> bool {
        self.next_u32() & 1 == 1
    }

    /// Synthetic int8 tensor data. Values are kept in a sub-range by
    /// default (`[-8, 8)`) so deep int32 accumulations stay far from
    /// overflow — matching how quantized models keep activations small.
    pub fn i8_vec(&mut self, n: usize) -> Vec<i8> {
        (0..n).map(|_| (self.next_below(16) as i64 - 8) as i8).collect()
    }

    /// Full-range int8 data for stress tests.
    pub fn i8_vec_full(&mut self, n: usize) -> Vec<i8> {
        (0..n).map(|_| self.next_u32() as i8).collect()
    }

    pub fn i32_vec(&mut self, n: usize, lo: i32, hi: i32) -> Vec<i32> {
        (0..n).map(|_| self.range_i64(lo as i64, hi as i64) as i32).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.next_below(i as u32 + 1) as usize;
            items.swap(i, j);
        }
    }

    /// Pick one element by reference.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.next_below(items.len() as u32) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Pcg32::seeded(7);
        let mut b = Pcg32::seeded(7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn seeds_differ() {
        let a: Vec<u32> = (0..8).map(|_| 0).collect();
        let mut r1 = Pcg32::seeded(1);
        let mut r2 = Pcg32::seeded(2);
        let s1: Vec<u32> = a.iter().map(|_| r1.next_u32()).collect();
        let s2: Vec<u32> = a.iter().map(|_| r2.next_u32()).collect();
        assert_ne!(s1, s2);
    }

    #[test]
    fn next_below_in_range() {
        let mut r = Pcg32::seeded(3);
        for bound in [1u32, 2, 3, 7, 100, 1 << 20] {
            for _ in 0..200 {
                assert!(r.next_below(bound) < bound);
            }
        }
    }

    #[test]
    fn range_inclusive_hits_endpoints() {
        let mut r = Pcg32::seeded(4);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..1000 {
            match r.range_i64(-2, 2) {
                -2 => saw_lo = true,
                2 => saw_hi = true,
                v => assert!((-2..=2).contains(&v)),
            }
        }
        assert!(saw_lo && saw_hi);
    }

    #[test]
    fn i8_vec_bounded() {
        let mut r = Pcg32::seeded(5);
        for v in r.i8_vec(512) {
            assert!((-8..8).contains(&(v as i32)));
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Pcg32::seeded(6);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(8);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
