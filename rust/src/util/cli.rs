//! Tiny CLI argument parser (no external crates available offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! subcommands. Used by the `vta` binary and the example/bench drivers.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    /// Declared option names (for typo detection); empty = accept anything.
    known: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (testable) — `argv[0]` must already
    /// be stripped.
    pub fn parse_from<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminator: rest is positional.
                    out.positional.extend(iter.by_ref());
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = iter.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn parse_env() -> Args {
        Self::parse_from(std::env::args().skip(1))
    }

    /// First positional argument, typically the subcommand.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| {
                v.parse::<usize>()
                    .unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'"))
            })
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| {
                v.parse::<u64>()
                    .unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'"))
            })
            .unwrap_or(default)
    }

    /// Comma-separated list parser behind the typed list getters. Empty
    /// items are ignored so a trailing comma is harmless.
    fn get_list<T: std::str::FromStr + Clone>(&self, name: &str, default: &[T]) -> Vec<T> {
        match self.get(name) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.parse::<T>().unwrap_or_else(|_| {
                        panic!("--{name} expects comma-separated integers, got '{v}'")
                    })
                })
                .collect(),
        }
    }

    /// Comma-separated integer list, e.g. `--blocks 16,32,64` (used by
    /// the sweep grid axes).
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Vec<usize> {
        self.get_list(name, default)
    }

    /// `get_usize_list` for u64 values (seed lists).
    pub fn get_u64_list(&self, name: &str, default: &[u64]) -> Vec<u64> {
        self.get_list(name, default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| {
                v.parse::<f64>()
                    .unwrap_or_else(|_| panic!("--{name} expects a number, got '{v}'"))
            })
            .unwrap_or(default)
    }

    /// Declare the full set of legal option/flag names; returns an error
    /// message listing unknown ones (typo protection for experiment
    /// drivers where a silently ignored flag would invalidate a run).
    pub fn check_known(&mut self, names: &[&str]) -> Result<(), String> {
        self.known = names.iter().map(|s| s.to_string()).collect();
        let mut unknown: Vec<&String> = Vec::new();
        for k in self.options.keys() {
            if !self.known.contains(k) {
                unknown.push(k);
            }
        }
        for f in &self.flags {
            if !self.known.contains(f) {
                unknown.push(f);
            }
        }
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(format!(
                "unknown option(s): {} (known: {})",
                unknown.iter().map(|s| format!("--{s}")).collect::<Vec<_>>().join(", "),
                names.join(", ")
            ))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse_from(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_subcommand() {
        let a = parse(&["run", "input.json"]);
        assert_eq!(a.subcommand(), Some("run"));
        assert_eq!(a.positional[1], "input.json");
    }

    #[test]
    fn options_both_styles() {
        let a = parse(&["--cfg", "default.json", "--steps=100"]);
        assert_eq!(a.get("cfg"), Some("default.json"));
        assert_eq!(a.get_usize("steps", 0), 100);
    }

    #[test]
    fn flags_vs_options() {
        let a = parse(&["--verbose", "--out", "x.txt", "--quiet"]);
        assert!(a.has_flag("verbose"));
        assert!(a.has_flag("quiet"));
        assert_eq!(a.get("out"), Some("x.txt"));
    }

    #[test]
    fn double_dash_terminator() {
        let a = parse(&["--a", "1", "--", "--not-a-flag"]);
        assert_eq!(a.get("a"), Some("1"));
        assert_eq!(a.positional, vec!["--not-a-flag"]);
    }

    #[test]
    fn unknown_detection() {
        let mut a = parse(&["--cfg", "x", "--tyop", "y"]);
        let err = a.check_known(&["cfg"]).unwrap_err();
        assert!(err.contains("tyop"));
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.get_or("missing", "d"), "d");
        assert_eq!(a.get_usize("n", 7), 7);
        assert_eq!(a.get_f64("x", 1.5), 1.5);
    }

    #[test]
    fn integer_lists() {
        let a = parse(&["--blocks", "16,32, 64,", "--seeds=1,2"]);
        assert_eq!(a.get_usize_list("blocks", &[8]), vec![16, 32, 64]);
        assert_eq!(a.get_u64_list("seeds", &[7]), vec![1, 2]);
        assert_eq!(a.get_usize_list("missing", &[4, 8]), vec![4, 8]);
    }

    #[test]
    #[should_panic(expected = "--blocks expects comma-separated integers")]
    fn integer_list_rejects_garbage() {
        parse(&["--blocks", "16,banana"]).get_usize_list("blocks", &[]);
    }
}
