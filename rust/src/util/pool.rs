//! Generic work-stealing worker pool (extracted from `sweep::queue` so
//! the serving runtime and the sweep engine share one implementation).
//!
//! Jobs vary enormously in cost (a 1×64×64 configuration at 224×224
//! simulates orders of magnitude more slowly than 1×16×16 at 56×56; a
//! serve batch of eight ResNet requests costs more than one micro-net
//! request), so static partitioning leaves workers idle. Jobs are
//! striped round-robin across per-worker deques at construction; a
//! worker pops from the front of its own deque and, when empty, steals
//! from the back of its neighbours'. Stealing from the opposite end
//! keeps contention low: owner and thief touch different ends of a
//! victim deque.
//!
//! `std::sync::Mutex` per deque is deliberate — job granularity is
//! whole network simulations or serve batches (milliseconds to
//! minutes), so lock traffic is noise and the std-only implementation
//! stays dependency-free.
//!
//! [`run_indexed`] is the convenience front door: evaluate a closure
//! over `0..jobs` across a scoped thread pool and collect the results
//! *in job order* — callers get parallel wall-clock with a result
//! vector indistinguishable from a serial loop's.

use std::collections::VecDeque;
use std::sync::Mutex;

pub struct JobQueue {
    deques: Vec<Mutex<VecDeque<usize>>>,
}

impl JobQueue {
    /// Distribute `jobs` (indices into the caller's job list) across
    /// `workers` deques, round-robin so expensive neighbours in grid
    /// order land on different workers.
    pub fn new(workers: usize, jobs: &[usize]) -> JobQueue {
        let workers = workers.max(1);
        let mut deques: Vec<VecDeque<usize>> = (0..workers).map(|_| VecDeque::new()).collect();
        for (i, &job) in jobs.iter().enumerate() {
            deques[i % workers].push_back(job);
        }
        JobQueue { deques: deques.into_iter().map(Mutex::new).collect() }
    }

    pub fn workers(&self) -> usize {
        self.deques.len()
    }

    /// Next job for `worker`: own deque first (front), then steal from
    /// the back of the nearest non-empty victim. `None` means every
    /// deque is empty — the worker can exit.
    pub fn pop(&self, worker: usize) -> Option<usize> {
        let me = worker % self.deques.len();
        if let Some(job) = self.deques[me].lock().unwrap().pop_front() {
            return Some(job);
        }
        for off in 1..self.deques.len() {
            let victim = (me + off) % self.deques.len();
            if let Some(job) = self.deques[victim].lock().unwrap().pop_back() {
                return Some(job);
            }
        }
        None
    }

    /// Jobs not yet handed out (racy under concurrency; for reporting).
    pub fn remaining(&self) -> usize {
        self.deques.iter().map(|d| d.lock().unwrap().len()).sum()
    }
}

/// Evaluate `f(0..jobs)` across up to `workers` scoped threads and
/// return the results in job-index order. The worker count only changes
/// wall clock, never the result vector: index `i` always holds `f(i)`.
/// A single worker (or a single job) runs inline with no threads at
/// all, so debugging a parallel caller under `workers = 1` is exactly
/// the serial program.
///
/// Panics in `f` propagate to the caller (scoped-thread semantics), so
/// a caller that must not die converts failures into a `Result` item
/// instead.
pub fn run_indexed<R, F>(workers: usize, jobs: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    if jobs == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, jobs);
    if workers == 1 {
        return (0..jobs).map(f).collect();
    }
    let indices: Vec<usize> = (0..jobs).collect();
    let queue = JobQueue::new(workers, &indices);
    let mut init: Vec<Option<R>> = Vec::with_capacity(jobs);
    init.resize_with(jobs, || None);
    let slots = Mutex::new(init);
    std::thread::scope(|scope| {
        for w in 0..workers {
            let queue = &queue;
            let slots = &slots;
            let f = &f;
            scope.spawn(move || {
                while let Some(j) = queue.pop(w) {
                    let r = f(j);
                    slots.lock().unwrap()[j] = Some(r);
                }
            });
        }
    });
    slots
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|r| r.expect("every job index is popped exactly once"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_job_popped_exactly_once_single_worker() {
        let jobs: Vec<usize> = (0..17).collect();
        let q = JobQueue::new(1, &jobs);
        let mut got = Vec::new();
        while let Some(j) = q.pop(0) {
            got.push(j);
        }
        assert_eq!(got, jobs);
    }

    #[test]
    fn stealing_drains_other_deques() {
        let jobs: Vec<usize> = (0..8).collect();
        let q = JobQueue::new(4, &jobs);
        // Worker 0 drains everything, stealing from workers 1..3.
        let mut got = Vec::new();
        while let Some(j) = q.pop(0) {
            got.push(j);
        }
        got.sort_unstable();
        assert_eq!(got, jobs);
        assert_eq!(q.remaining(), 0);
    }

    #[test]
    fn concurrent_workers_partition_the_jobs() {
        let jobs: Vec<usize> = (0..64).collect();
        let q = JobQueue::new(4, &jobs);
        let got = Mutex::new(Vec::new());
        std::thread::scope(|s| {
            for w in 0..4 {
                let q = &q;
                let got = &got;
                s.spawn(move || {
                    while let Some(j) = q.pop(w) {
                        got.lock().unwrap().push(j);
                    }
                });
            }
        });
        let mut got = got.into_inner().unwrap();
        got.sort_unstable();
        assert_eq!(got, jobs, "each job must be handed out exactly once");
    }

    #[test]
    fn more_workers_than_jobs() {
        let jobs = [0usize, 1];
        let q = JobQueue::new(8, &jobs);
        assert_eq!(q.pop(5), Some(0));
        assert_eq!(q.pop(5), Some(1));
        assert_eq!(q.pop(5), None);
        assert_eq!(q.pop(0), None);
    }

    #[test]
    fn zero_workers_clamped() {
        let q = JobQueue::new(0, &[3]);
        assert_eq!(q.workers(), 1);
        assert_eq!(q.pop(0), Some(3));
    }

    #[test]
    fn run_indexed_preserves_job_order() {
        for workers in [0usize, 1, 3, 8] {
            let got = run_indexed(workers, 23, |i| i * i);
            let want: Vec<usize> = (0..23).map(|i| i * i).collect();
            assert_eq!(got, want, "workers={workers}");
        }
    }

    #[test]
    fn run_indexed_handles_empty_and_non_clone_results() {
        let empty: Vec<String> = run_indexed(4, 0, |i| i.to_string());
        assert!(empty.is_empty());
        // String is Send but the slots path must not require Clone.
        let got = run_indexed(4, 5, |i| format!("job-{i}"));
        assert_eq!(got[4], "job-4");
    }
}
