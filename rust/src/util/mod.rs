//! Self-contained substrate utilities (no external crates are reachable
//! offline, so JSON, CLI parsing, PRNG, stats, benching, property
//! testing, and the work-stealing thread pool are implemented here from
//! scratch).

pub mod bench;
pub mod bitfield;
pub mod cli;
pub mod fsx;
pub mod hash;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
