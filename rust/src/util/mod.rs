//! Self-contained substrate utilities (no external crates are reachable
//! offline, so JSON, CLI parsing, PRNG, stats, benching and property
//! testing are implemented here from scratch).

pub mod bench;
pub mod bitfield;
pub mod cli;
pub mod hash;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
