//! Mini property-testing framework (proptest is not in the offline vendor
//! set). Provides seeded random case generation, a configurable case
//! count, and greedy input shrinking for integer-vector-shaped cases.
//!
//! Usage (`no_run`: doctest executables can't resolve the XLA rpath):
//! ```no_run
//! use vta::util::prop::{Prop, Gen};
//! Prop::new("add-commutes").cases(256).run(|g| {
//!     let a = g.i64(-1000, 1000);
//!     let b = g.i64(-1000, 1000);
//!     assert_eq!(a + b, b + a);
//!     Ok(())
//! });
//! ```

use super::rng::Pcg32;

/// Per-case generator handed to the property closure. Records every draw
/// so failing cases can be replayed and shrunk.
pub struct Gen {
    rng: Pcg32,
    /// Log of (lo, hi, value) integer draws for shrink replay.
    draws: Vec<(i64, i64, i64)>,
    /// When replaying a shrunk candidate, values come from here instead of
    /// the RNG.
    replay: Option<Vec<i64>>,
    cursor: usize,
}

impl Gen {
    fn fresh(seed: u64) -> Gen {
        Gen { rng: Pcg32::seeded(seed), draws: Vec::new(), replay: None, cursor: 0 }
    }

    fn replaying(values: Vec<i64>) -> Gen {
        Gen {
            rng: Pcg32::seeded(0),
            draws: Vec::new(),
            replay: Some(values),
            cursor: 0,
        }
    }

    /// Draw an integer in `[lo, hi]` — the primitive all other generators
    /// build on.
    pub fn i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi, "empty range");
        let v = if let Some(replay) = &self.replay {
            let raw = replay.get(self.cursor).copied().unwrap_or(lo);
            self.cursor += 1;
            raw.clamp(lo, hi)
        } else {
            self.rng.range_i64(lo, hi)
        };
        self.draws.push((lo, hi, v));
        v
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.i64(lo as i64, hi as i64) as usize
    }

    pub fn bool(&mut self) -> bool {
        self.i64(0, 1) == 1
    }

    pub fn i8(&mut self) -> i8 {
        self.i64(i8::MIN as i64, i8::MAX as i64) as i8
    }

    /// Power-of-two in `[2^lo_log, 2^hi_log]` — ubiquitous in VTA configs.
    pub fn pow2(&mut self, lo_log: u32, hi_log: u32) -> usize {
        1usize << self.i64(lo_log as i64, hi_log as i64)
    }

    pub fn vec_i8(&mut self, len: usize) -> Vec<i8> {
        (0..len).map(|_| self.i8()).collect()
    }

    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize(0, items.len() - 1)]
    }
}

pub struct Prop {
    name: String,
    cases: usize,
    seed: u64,
    max_shrink_steps: usize,
}

impl Prop {
    pub fn new(name: &str) -> Prop {
        // VTA_PROP_CASES lets CI scale effort without code changes.
        let cases = std::env::var("VTA_PROP_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(128);
        Prop { name: name.to_string(), cases, seed: 0x5eed, max_shrink_steps: 400 }
    }

    pub fn cases(mut self, n: usize) -> Prop {
        self.cases = n;
        self
    }

    pub fn seed(mut self, seed: u64) -> Prop {
        self.seed = seed;
        self
    }

    /// Run the property over `cases` random inputs; on failure, shrink the
    /// recorded draw vector greedily (each draw toward its lower bound,
    /// then halving) and panic with the minimal reproduction.
    pub fn run<F>(self, mut prop: F)
    where
        F: FnMut(&mut Gen) -> Result<(), String>,
    {
        for case in 0..self.cases {
            let case_seed = self.seed ^ (case as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
            let mut g = Gen::fresh(case_seed);
            let outcome = prop(&mut g);
            if let Err(msg) = outcome {
                let draws = g.draws.clone();
                let (min_draws, min_msg) =
                    self.shrink(draws, msg, &mut prop);
                panic!(
                    "property '{}' failed (case {case}, seed {case_seed:#x}): {}\n  minimal draws: {:?}",
                    self.name, min_msg,
                    min_draws.iter().map(|(_, _, v)| *v).collect::<Vec<_>>()
                );
            }
        }
    }

    fn shrink<F>(
        &self,
        mut draws: Vec<(i64, i64, i64)>,
        mut msg: String,
        prop: &mut F,
    ) -> (Vec<(i64, i64, i64)>, String)
    where
        F: FnMut(&mut Gen) -> Result<(), String>,
    {
        let mut steps = 0;
        let mut progress = true;
        while progress && steps < self.max_shrink_steps {
            progress = false;
            for i in 0..draws.len() {
                let (lo, _hi, v) = draws[i];
                if v == lo {
                    continue;
                }
                // Candidate values, most aggressive first.
                for cand in [lo, lo + (v - lo) / 2, v - 1] {
                    if cand == v {
                        continue;
                    }
                    let mut candidate = draws.clone();
                    candidate[i].2 = cand;
                    let values: Vec<i64> = candidate.iter().map(|d| d.2).collect();
                    let mut g = Gen::replaying(values);
                    steps += 1;
                    if let Err(new_msg) = prop(&mut g) {
                        // still failing — keep the smaller case (use the
                        // replay-recorded draws, which may differ in length)
                        draws = g.draws.clone();
                        msg = new_msg;
                        progress = true;
                        break;
                    }
                    if steps >= self.max_shrink_steps {
                        break;
                    }
                }
                if steps >= self.max_shrink_steps {
                    break;
                }
            }
        }
        (draws, msg)
    }
}

/// Assertion helper returning `Err` instead of panicking, so the runner
/// can shrink.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

/// Equality assertion variant of [`prop_assert!`].
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {} ({:?} vs {:?})",
                stringify!($a),
                stringify!($b),
                a,
                b
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        Prop::new("count").cases(50).run(|g| {
            let _ = g.i64(0, 10);
            count += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics() {
        Prop::new("fails").cases(50).run(|g| {
            let v = g.i64(0, 100);
            if v >= 10 {
                Err(format!("v={v} too big"))
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn shrinking_minimizes() {
        // Catch the panic and confirm the counterexample shrank to the
        // boundary (v == 10).
        let result = std::panic::catch_unwind(|| {
            Prop::new("shrinks").cases(50).run(|g| {
                let v = g.i64(0, 1000);
                if v >= 10 {
                    Err("too big".into())
                } else {
                    Ok(())
                }
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("minimal draws: [10]"), "got: {msg}");
    }

    #[test]
    fn pow2_in_bounds() {
        Prop::new("pow2").cases(64).run(|g| {
            let v = g.pow2(0, 10);
            prop_assert!(v.is_power_of_two() && v <= 1024, "bad pow2 {v}");
            Ok(())
        });
    }
}
