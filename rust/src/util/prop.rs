//! Mini property-testing framework (proptest is not in the offline vendor
//! set). Provides seeded random case generation, a configurable case
//! count, and greedy input shrinking for integer-vector-shaped cases.
//!
//! Usage (`no_run`: doctest executables can't resolve the XLA rpath):
//! ```no_run
//! use vta::util::prop::{Prop, Gen};
//! Prop::new("add-commutes").cases(256).run(|g| {
//!     let a = g.i64(-1000, 1000);
//!     let b = g.i64(-1000, 1000);
//!     assert_eq!(a + b, b + a);
//!     Ok(())
//! });
//! ```

use super::rng::Pcg32;

/// Per-case generator handed to the property closure. Records every draw
/// so failing cases can be replayed and shrunk.
pub struct Gen {
    rng: Pcg32,
    /// Log of (lo, hi, value) integer draws for shrink replay.
    draws: Vec<(i64, i64, i64)>,
    /// When replaying a shrunk candidate, values come from here instead of
    /// the RNG.
    replay: Option<Vec<i64>>,
    cursor: usize,
}

impl Gen {
    fn fresh(seed: u64) -> Gen {
        Gen { rng: Pcg32::seeded(seed), draws: Vec::new(), replay: None, cursor: 0 }
    }

    fn replaying(values: Vec<i64>) -> Gen {
        Gen {
            rng: Pcg32::seeded(0),
            draws: Vec::new(),
            replay: Some(values),
            cursor: 0,
        }
    }

    /// Draw an integer in `[lo, hi]` — the primitive all other generators
    /// build on.
    pub fn i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi, "empty range");
        let v = if let Some(replay) = &self.replay {
            let raw = replay.get(self.cursor).copied().unwrap_or(lo);
            self.cursor += 1;
            raw.clamp(lo, hi)
        } else {
            self.rng.range_i64(lo, hi)
        };
        self.draws.push((lo, hi, v));
        v
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.i64(lo as i64, hi as i64) as usize
    }

    pub fn bool(&mut self) -> bool {
        self.i64(0, 1) == 1
    }

    pub fn i8(&mut self) -> i8 {
        self.i64(i8::MIN as i64, i8::MAX as i64) as i8
    }

    /// Power-of-two in `[2^lo_log, 2^hi_log]` — ubiquitous in VTA configs.
    pub fn pow2(&mut self, lo_log: u32, hi_log: u32) -> usize {
        1usize << self.i64(lo_log as i64, hi_log as i64)
    }

    pub fn vec_i8(&mut self, len: usize) -> Vec<i8> {
        (0..len).map(|_| self.i8()).collect()
    }

    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize(0, items.len() - 1)]
    }
}

pub struct Prop {
    name: String,
    cases: usize,
    seed: u64,
    max_shrink_steps: usize,
}

impl Prop {
    pub fn new(name: &str) -> Prop {
        // VTA_PROP_CASES lets CI scale effort without code changes.
        let cases = std::env::var("VTA_PROP_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(128);
        Prop { name: name.to_string(), cases, seed: 0x5eed, max_shrink_steps: 400 }
    }

    pub fn cases(mut self, n: usize) -> Prop {
        self.cases = n;
        self
    }

    pub fn seed(mut self, seed: u64) -> Prop {
        self.seed = seed;
        self
    }

    /// Run the property over `cases` random inputs; on failure, shrink the
    /// recorded draw vector greedily (each draw toward its lower bound,
    /// then halving) and panic with the minimal reproduction.
    pub fn run<F>(self, mut prop: F)
    where
        F: FnMut(&mut Gen) -> Result<(), String>,
    {
        for case in 0..self.cases {
            let case_seed = self.seed ^ (case as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
            let mut g = Gen::fresh(case_seed);
            let outcome = prop(&mut g);
            if let Err(msg) = outcome {
                let draws = g.draws.clone();
                let (min_draws, min_msg) =
                    self.shrink(draws, msg, &mut prop);
                panic!(
                    "property '{}' failed (case {case}, seed {case_seed:#x}): {}\n  minimal draws: {:?}",
                    self.name, min_msg,
                    min_draws.iter().map(|(_, _, v)| *v).collect::<Vec<_>>()
                );
            }
        }
    }

    fn shrink<F>(
        &self,
        mut draws: Vec<(i64, i64, i64)>,
        mut msg: String,
        prop: &mut F,
    ) -> (Vec<(i64, i64, i64)>, String)
    where
        F: FnMut(&mut Gen) -> Result<(), String>,
    {
        let mut steps = 0;
        let mut progress = true;
        while progress && steps < self.max_shrink_steps {
            progress = false;
            for i in 0..draws.len() {
                let (lo, _hi, v) = draws[i];
                if v == lo {
                    continue;
                }
                // Candidate values, most aggressive first.
                for cand in [lo, lo + (v - lo) / 2, v - 1] {
                    if cand == v {
                        continue;
                    }
                    let mut candidate = draws.clone();
                    candidate[i].2 = cand;
                    let values: Vec<i64> = candidate.iter().map(|d| d.2).collect();
                    let mut g = Gen::replaying(values);
                    steps += 1;
                    if let Err(new_msg) = prop(&mut g) {
                        // still failing — keep the smaller case (use the
                        // replay-recorded draws, which may differ in length)
                        draws = g.draws.clone();
                        msg = new_msg;
                        progress = true;
                        break;
                    }
                    if steps >= self.max_shrink_steps {
                        break;
                    }
                }
                if steps >= self.max_shrink_steps {
                    break;
                }
            }
        }
        (draws, msg)
    }
}

/// Draw a random — but always structurally valid — operator graph for
/// the cross-backend differential fuzz harness
/// (`rust/tests/backend_parity.rs`). Two regimes, chosen per case:
/// *sequence* graphs (`w == 1`) walk the attention/LSTM operator menu
/// (1×1 GEMMs, residual adds, elementwise multiplies, hard-sigmoid /
/// hard-tanh, layernorm-approx, softmax-approx, channel slices, full
/// multi-head attention bundles); *image* graphs walk the CNN menu
/// (3×3/1×1 convs, depthwise, maxpool, residual adds, an optional
/// global-pool + dense tail). Channel counts stay multiples of `block`
/// so most layers take the accelerator path; synthetic weights come
/// from a seed drawn through `g`, keeping the shrinkable draw log
/// small.
pub fn gen_graph(g: &mut Gen, block: usize) -> crate::compiler::graph::Graph {
    use crate::compiler::cpu_ref::default_shift;
    use crate::compiler::graph::{Graph, Op};
    use crate::compiler::layout::Shape;
    use crate::util::rng::Pcg32;

    fn conv(
        graph: &mut Graph,
        wrng: &mut Pcg32,
        name: &str,
        from: usize,
        c_in: usize,
        c_out: usize,
        k: usize,
        relu: bool,
    ) -> usize {
        let op = Op::Conv {
            c_out,
            k,
            stride: 1,
            pad: k / 2,
            shift: default_shift(c_in * k * k),
            relu,
            weights: wrng.i8_vec(c_out * c_in * k * k),
        };
        graph.add(name, op, vec![from])
    }

    let seq_mode = g.bool();
    let mut wrng = Pcg32::seeded(g.i64(0, 1 << 30) as u64);
    let mut hh = g.usize(2, 5);
    let mut ww = if seq_mode { 1 } else { hh };
    let mut c = block * g.usize(1, 2);
    let mut graph = Graph::new("fuzz", Shape::new(c, hh, ww));
    let mut cur = 0usize;
    for i in 0..g.usize(2, 6) {
        match g.usize(0, if seq_mode { 7 } else { 6 }) {
            0 => {
                let c_out = block * g.usize(1, 2);
                let k = if seq_mode || g.bool() { 1 } else { 3 };
                cur = conv(&mut graph, &mut wrng, &format!("conv{i}"), cur, c, c_out, k, g.bool());
                c = c_out;
            }
            1 => {
                // Residual add through a materialized 1×1 branch.
                let b = conv(&mut graph, &mut wrng, &format!("br{i}"), cur, c, c, 1, false);
                cur = graph.add(&format!("add{i}"), Op::Add { relu: g.bool() }, vec![b, cur]);
            }
            2 => {
                let b = conv(&mut graph, &mut wrng, &format!("gate{i}"), cur, c, c, 1, false);
                let op = Op::EltMul { shift: g.usize(0, 7) as u32, relu: g.bool() };
                cur = graph.add(&format!("mul{i}"), op, vec![b, cur]);
            }
            3 => cur = graph.add(&format!("sig{i}"), Op::HardSigmoid, vec![cur]),
            4 => cur = graph.add(&format!("tanh{i}"), Op::HardTanh, vec![cur]),
            5 if seq_mode => {
                if c.is_power_of_two() {
                    cur = graph.add(&format!("ln{i}"), Op::LayerNormApprox, vec![cur]);
                } else {
                    let op = Op::SoftmaxApprox { shift: g.usize(1, 4) as u32 };
                    cur = graph.add(&format!("sm{i}"), op, vec![cur]);
                }
            }
            6 if seq_mode => {
                if c > block {
                    let start = g.usize(0, c - block);
                    let op = Op::ChanSlice { start, len: block };
                    cur = graph.add(&format!("slice{i}"), op, vec![cur]);
                    c = block;
                } else {
                    let op = Op::SoftmaxApprox { shift: g.usize(1, 4) as u32 };
                    cur = graph.add(&format!("sm{i}"), op, vec![cur]);
                }
            }
            7 => {
                // Full attention bundle: QKV → scores → softmax →
                // transpose → mix. Restores the input shape.
                let heads = if c % 2 == 0 && g.bool() { 2 } else { 1 };
                let q = conv(&mut graph, &mut wrng, &format!("q{i}"), cur, c, c, 1, false);
                let k = conv(&mut graph, &mut wrng, &format!("k{i}"), cur, c, c, 1, false);
                let v = conv(&mut graph, &mut wrng, &format!("v{i}"), cur, c, c, 1, false);
                let op = Op::AttnScores { heads, shift: default_shift(c / heads) };
                let s = graph.add(&format!("scores{i}"), op, vec![q, k]);
                let p = graph.add(&format!("sm{i}"), Op::SoftmaxApprox { shift: 2 }, vec![s]);
                let t = graph.add(&format!("pt{i}"), Op::HeadTranspose { heads }, vec![p]);
                let op = Op::AttnMix { heads, shift: default_shift(hh) };
                cur = graph.add(&format!("mix{i}"), op, vec![t, v]);
            }
            5 => {
                // Image mode: depthwise (shape-preserving).
                let op = Op::Depthwise {
                    k: 3,
                    stride: 1,
                    pad: 1,
                    shift: default_shift(9),
                    relu: g.bool(),
                    weights: wrng.i8_vec(c * 9),
                };
                cur = graph.add(&format!("dw{i}"), op, vec![cur]);
            }
            6 => {
                if hh >= 2 && ww >= 2 {
                    let op = Op::MaxPool { k: 2, stride: 2, pad: 0 };
                    cur = graph.add(&format!("pool{i}"), op, vec![cur]);
                    hh = (hh - 2) / 2 + 1;
                    ww = (ww - 2) / 2 + 1;
                } else {
                    cur = graph.add(&format!("tanh{i}"), Op::HardTanh, vec![cur]);
                }
            }
            _ => unreachable!(),
        }
    }
    if !seq_mode && g.bool() {
        let gap = graph.add("gap", Op::GlobalAvgPool, vec![cur]);
        let units = g.usize(4, 12);
        let op = Op::Dense {
            units,
            shift: default_shift(c),
            relu: false,
            weights: wrng.i8_vec(units * c),
        };
        graph.add("fc", op, vec![gap]);
    }
    graph
}

/// Assertion helper returning `Err` instead of panicking, so the runner
/// can shrink.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

/// Equality assertion variant of [`prop_assert!`].
#[macro_export]
macro_rules! prop_assert_eq {
    ($a:expr, $b:expr) => {{
        let (a, b) = (&$a, &$b);
        if a != b {
            return Err(format!(
                "{} != {} ({:?} vs {:?})",
                stringify!($a),
                stringify!($b),
                a,
                b
            ));
        }
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        Prop::new("count").cases(50).run(|g| {
            let _ = g.i64(0, 10);
            count += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics() {
        Prop::new("fails").cases(50).run(|g| {
            let v = g.i64(0, 100);
            if v >= 10 {
                Err(format!("v={v} too big"))
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn shrinking_minimizes() {
        // Catch the panic and confirm the counterexample shrank to the
        // boundary (v == 10).
        let result = std::panic::catch_unwind(|| {
            Prop::new("shrinks").cases(50).run(|g| {
                let v = g.i64(0, 1000);
                if v >= 10 {
                    Err("too big".into())
                } else {
                    Ok(())
                }
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("minimal draws: [10]"), "got: {msg}");
    }

    #[test]
    fn gen_graph_is_always_valid() {
        Prop::new("gen-graph-valid").cases(64).run(|g| {
            let graph = gen_graph(g, 4);
            graph.validate().map_err(|e| format!("invalid graph: {e}"))
        });
    }

    #[test]
    fn pow2_in_bounds() {
        Prop::new("pow2").cases(64).run(|g| {
            let v = g.pow2(0, 10);
            prop_assert!(v.is_power_of_two() && v <= 1024, "bad pow2 {v}");
            Ok(())
        });
    }
}
