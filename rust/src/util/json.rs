//! Minimal JSON parser/serializer.
//!
//! The paper's stack is driven by a JSON configuration file ("A JSON
//! configuration file is the only compile-time construct consumed by the
//! compiler, runtime, as well as all hardware targets", §II-B). No JSON
//! crate is available in the offline vendor set, so this module implements
//! the subset we need from scratch: objects, arrays, strings, numbers
//! (i64/f64), booleans and null, with precise error positions.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Object keys are kept sorted (BTreeMap) so that
/// serialization is deterministic — important for artifact fingerprinting.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Int(i64),
    Float(f64),
    Str(String),
    Array(Vec<Json>),
    Object(BTreeMap<String, Json>),
}

/// Parse error with byte offset and a short message.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after top-level value"));
        }
        Ok(v)
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(v) => Some(*v),
            Json::Float(v) if v.fract() == 0.0 => Some(*v as i64),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Int(v) => Some(*v as f64),
            Json::Float(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Object(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_object().and_then(|o| o.get(key))
    }

    /// Serialize compactly (no whitespace).
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Int(v) => out.push_str(&v.to_string()),
            Json::Float(v) => {
                if v.is_finite() {
                    // Ensure round-trippable floats keep a decimal point.
                    let s = format!("{v}");
                    out.push_str(&s);
                    if !s.contains('.') && !s.contains('e') && !s.contains('E') {
                        out.push_str(".0");
                    }
                } else {
                    out.push_str("null"); // JSON has no Inf/NaN
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Array(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Object(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Object(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Array(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16
                                + (d as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex digit in \\u"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape character")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control character in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let end = (start + len).min(self.bytes.len());
                        match std::str::from_utf8(&self.bytes[start..end]) {
                            Ok(chunk) => {
                                s.push_str(chunk);
                                self.pos = end;
                            }
                            Err(_) => return Err(self.err("invalid utf-8 in string")),
                        }
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            is_float = true;
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        if is_float {
            text.parse::<f64>()
                .map(Json::Float)
                .map_err(|_| self.err("invalid float literal"))
        } else {
            match text.parse::<i64>() {
                Ok(v) => Ok(Json::Int(v)),
                // Integer overflow: fall back to float like most parsers.
                Err(_) => text
                    .parse::<f64>()
                    .map(Json::Float)
                    .map_err(|_| self.err("invalid number literal")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    if first >= 0xf0 {
        4
    } else if first >= 0xe0 {
        3
    } else {
        2
    }
}

/// Convenience builder for objects: `obj([("a", Json::Int(1))])`.
pub fn obj<I: IntoIterator<Item = (&'static str, Json)>>(fields: I) -> Json {
    Json::Object(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-42").unwrap(), Json::Int(-42));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Float(3.5));
        assert_eq!(Json::parse("1e3").unwrap(), Json::Float(1000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(arr[0].as_i64(), Some(1));
        assert_eq!(arr[2].get("b").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrips() {
        let cases = [
            r#"{"a":[1,2,3],"b":{"c":true,"d":null},"e":-7,"f":1.25,"s":"q\"uote"}"#,
            r#"[]"#,
            r#"{}"#,
            r#"[[[[1]]]]"#,
        ];
        for c in cases {
            let v = Json::parse(c).unwrap();
            let s = v.to_string_compact();
            assert_eq!(Json::parse(&s).unwrap(), v, "roundtrip failed for {c}");
        }
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::parse(r#"{"x": [1, {"y": "z"}], "w": 2.5}"#).unwrap();
        assert_eq!(Json::parse(&v.to_string_pretty()).unwrap(), v);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé");
        let v = Json::parse("\"héllo\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo");
    }
}
