//! Bucketed event wheel for tsim's event-skip core.
//!
//! The old `Tsim::advance_time` re-derived the next wake time with a
//! linear scan over every driver, queue and the VME on every skip. The
//! wheel inverts that: every *pure-time* event (a VME burst completion,
//! a pad-fill finish, a compute `busy_until`) is scheduled once, at the
//! moment its time becomes known, and `advance_time` just asks for the
//! next pending wake. Condition-chained enablements (a token push that
//! unblocks a pop, queue space freeing, instruction dispatch) need no
//! scheduling at all: they are always caused by *progress* in the
//! current cycle, and the core wakes at `now + 1` whenever progress
//! happened (see `Tsim::advance_time`).
//!
//! Invariants (the ones DESIGN.md §"Event core & SIMD dispatch" leans
//! on):
//!
//! * **Level-triggered wakes.** A wake is only a hint: every simulator
//!   condition is re-checked by the woken step. Spurious or duplicate
//!   wakes are no-op cycles and cannot change the timeline, so the wheel
//!   may clamp past times, drop already-passed bits on rotation, and
//!   deliver an overflow event early after a same-time duplicate.
//! * **No missed wakes.** `schedule` never discards a future time, and
//!   `next_after(now)` returns the minimum pending time `> now` (the
//!   near-window bitset is refilled from the overflow heap before it is
//!   scanned).
//!
//! Layout: a 256-cycle near-future window as a 4×u64 bitset anchored at
//! `base` (bit *d* of the window = cycle `base + d`), plus a min-heap
//! for events beyond the horizon. Rotation shifts the window rather than
//! walking cycle-by-cycle, so a long skip costs O(1).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Near-future horizon in cycles (bitset capacity).
const HORIZON: u64 = 256;
const WORDS: usize = (HORIZON / 64) as usize;

/// Calendar queue of pending wake times. See the module docs.
#[derive(Debug, Default)]
pub struct EventWheel {
    /// Cycle represented by bit 0 of `bits[0]`.
    base: u64,
    bits: [u64; WORDS],
    /// Events at `base + HORIZON` or later.
    overflow: BinaryHeap<Reverse<u64>>,
}

impl EventWheel {
    pub fn new() -> EventWheel {
        EventWheel::default()
    }

    /// Record that something may happen at cycle `at`. Past times clamp
    /// to the window base: a stale wake is a no-op step, never an error.
    pub fn schedule(&mut self, at: u64) {
        let at = at.max(self.base);
        let d = at - self.base;
        if d < HORIZON {
            self.bits[(d / 64) as usize] |= 1u64 << (d % 64);
        } else {
            self.overflow.push(Reverse(at));
        }
    }

    /// Earliest scheduled cycle strictly after `now`. Rotates the window
    /// to `now + 1` (dropping past bits — safe under the level-triggered
    /// invariant) and refills it from the overflow heap before scanning.
    /// `None` when nothing is pending.
    pub fn next_after(&mut self, now: u64) -> Option<u64> {
        self.rotate_to(now + 1);
        for (wi, &word) in self.bits.iter().enumerate() {
            if word != 0 {
                return Some(self.base + wi as u64 * 64 + word.trailing_zeros() as u64);
            }
        }
        // Near window empty: the heap minimum (if any) is next. It is
        // consumed here — the caller jumps straight to it, which is the
        // wake it asked for.
        self.overflow.pop().map(|Reverse(t)| t.max(self.base))
    }

    /// Drop every pending event (program teardown / session reuse).
    pub fn clear(&mut self) {
        self.bits = [0; WORDS];
        self.overflow.clear();
    }

    fn rotate_to(&mut self, new_base: u64) {
        if new_base <= self.base {
            return;
        }
        let delta = new_base - self.base;
        self.base = new_base;
        if delta >= HORIZON {
            self.bits = [0; WORDS];
        } else {
            shift_down(&mut self.bits, delta);
        }
        while let Some(&Reverse(t)) = self.overflow.peek() {
            if t >= self.base + HORIZON {
                break;
            }
            self.overflow.pop();
            let d = t.saturating_sub(self.base);
            self.bits[(d / 64) as usize] |= 1u64 << (d % 64);
        }
    }
}

/// Shift the 256-bit window down by `delta` bits (`0 < delta < HORIZON`),
/// discarding the low bits and zero-filling the top.
fn shift_down(bits: &mut [u64; WORDS], delta: u64) {
    let words = (delta / 64) as usize;
    let b = (delta % 64) as u32;
    if words > 0 {
        for i in 0..WORDS {
            bits[i] = if i + words < WORDS { bits[i + words] } else { 0 };
        }
    }
    if b > 0 {
        for i in 0..WORDS {
            let hi = if i + 1 < WORDS { bits[i + 1] } else { 0 };
            bits[i] = (bits[i] >> b) | (hi << (64 - b));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn returns_events_in_order() {
        let mut w = EventWheel::new();
        w.schedule(5);
        w.schedule(3);
        w.schedule(200);
        assert_eq!(w.next_after(0), Some(3));
        assert_eq!(w.next_after(3), Some(5));
        assert_eq!(w.next_after(5), Some(200));
        assert_eq!(w.next_after(200), None);
    }

    #[test]
    fn strictly_after_now() {
        let mut w = EventWheel::new();
        w.schedule(10);
        assert_eq!(w.next_after(9), Some(10));
        let mut w = EventWheel::new();
        w.schedule(10);
        assert_eq!(w.next_after(10), None, "events at now are not 'after'");
    }

    #[test]
    fn overflow_heap_refills_window() {
        let mut w = EventWheel::new();
        w.schedule(1_000_000);
        w.schedule(500);
        w.schedule(100_000);
        assert_eq!(w.next_after(0), Some(500));
        assert_eq!(w.next_after(500), Some(100_000));
        assert_eq!(w.next_after(100_000), Some(1_000_000));
        assert_eq!(w.next_after(1_000_000), None);
    }

    #[test]
    fn past_times_clamp_and_drop() {
        let mut w = EventWheel::new();
        assert_eq!(w.next_after(50), None);
        w.schedule(10); // already in the past relative to the base
        let n = w.next_after(60);
        // Either dropped or clamped to a stale (harmless) wake <= base;
        // it must never report a *future* phantom event.
        assert!(n.is_none() || n.unwrap() <= 61, "got {n:?}");
    }

    #[test]
    fn duplicates_collapse_or_repeat_harmlessly() {
        let mut w = EventWheel::new();
        w.schedule(40);
        w.schedule(40);
        w.schedule(40);
        assert_eq!(w.next_after(0), Some(40));
        assert_eq!(w.next_after(40), None);
    }

    #[test]
    fn duplicate_overflow_events_stay_in_order() {
        let mut w = EventWheel::new();
        w.schedule(10_000);
        w.schedule(10_000);
        w.schedule(20_000);
        assert_eq!(w.next_after(0), Some(10_000));
        // The duplicate may surface as a stale wake at/before 10_001;
        // the next *new* event must still be 20_000.
        let mut t = 10_000;
        loop {
            match w.next_after(t) {
                Some(n) if n < 20_000 => t = n,
                other => {
                    assert_eq!(other, Some(20_000));
                    break;
                }
            }
        }
        assert_eq!(w.next_after(20_000), None);
    }

    #[test]
    fn long_jumps_cost_one_rotation() {
        let mut w = EventWheel::new();
        w.schedule(3);
        w.schedule(1 << 40);
        assert_eq!(w.next_after(0), Some(3));
        assert_eq!(w.next_after(3), Some(1 << 40));
        w.schedule((1 << 40) + 7);
        assert_eq!(w.next_after(1 << 40), Some((1 << 40) + 7));
    }

    #[test]
    fn clear_empties_everything() {
        let mut w = EventWheel::new();
        w.schedule(5);
        w.schedule(99_999);
        w.clear();
        assert_eq!(w.next_after(0), None);
    }

    #[test]
    fn window_boundary_events() {
        let mut w = EventWheel::new();
        w.schedule(HORIZON - 1); // last in-window bit
        w.schedule(HORIZON); // first overflow event
        assert_eq!(w.next_after(0), Some(HORIZON - 1));
        assert_eq!(w.next_after(HORIZON - 1), Some(HORIZON));
        assert_eq!(w.next_after(HORIZON), None);
    }
}
