//! VTA Memory Engine (VME) — the enhanced memory subsystem of §IV-A3 and
//! Fig 5/6.
//!
//! Models a parameterized AXI interface (8..=64 bytes/cycle) with:
//!
//! * **multiple outstanding requests** bounded by the tag-buffer size
//!   (`vme_inflight`; the original VTA behaviour is recovered with 1),
//! * **out-of-order completion** across owners, in-order data streaming
//!   per channel (one burst occupies the data channel at a time),
//! * separate read and write data channels (AXI R/W channels), so loads
//!   and stores overlap — which is what makes double buffering effective,
//! * a fixed request latency before the first data beat; with multiple
//!   tags, latency of queued requests is hidden behind active bursts
//!   (Fig 6: "multiple memory load requests to be inflight
//!   simultaneously").
//!
//! §Perf: bursts are scheduled *analytically* — each channel is a FIFO
//! server, so a burst's completion time is known at issue
//! (`max(ready, channel_free) + ceil(bytes/width)`). This is cycle-exact
//! with the naive beat-by-beat model (the FIFO discipline admits no
//! preemption by later requests) and removes the per-cycle stepping that
//! dominated simulator wall time.

/// The four bus masters that talk to the VME.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Owner {
    Fetch,
    Load,
    Compute,
    Store,
}

pub type ReqId = u64;

/// AXI4 caps bursts at 256 beats; longer transfers are split by callers
/// using [`Vme::split_bursts`].
pub const MAX_BURST_BEATS: u64 = 256;

#[derive(Debug, Default, Clone, Copy)]
pub struct VmeCounters {
    pub bytes_read: u64,
    pub bytes_written: u64,
    pub read_busy_cycles: u64,
    pub write_busy_cycles: u64,
    pub requests: u64,
}

#[derive(Debug, Clone, Copy)]
struct Completion {
    owner: Owner,
    id: ReqId,
    /// Cycle at which the full burst has been delivered.
    at: u64,
}

#[derive(Debug)]
pub struct Vme {
    axi_bytes: u64,
    latency: u64,
    max_inflight: usize,
    next_id: ReqId,
    /// Cycle at which each data channel becomes free.
    read_free: u64,
    write_free: u64,
    /// Scheduled, undelivered-or-undrained completions (tags in use).
    completions: Vec<Completion>,
    pub counters: VmeCounters,
}

impl Vme {
    pub fn new(axi_bytes: usize, latency: u64, max_inflight: usize) -> Vme {
        Vme {
            axi_bytes: axi_bytes as u64,
            latency,
            max_inflight,
            next_id: 1,
            read_free: 0,
            write_free: 0,
            completions: Vec::new(),
            counters: VmeCounters::default(),
        }
    }

    pub fn axi_bytes(&self) -> u64 {
        self.axi_bytes
    }

    /// Whether a new request can be accepted at `now` (a tag frees when
    /// its burst has fully completed).
    pub fn can_issue(&self, now: u64) -> bool {
        self.completions.iter().filter(|c| c.at > now).count() < self.max_inflight
    }

    /// Issue a burst; its completion time is computed analytically.
    /// Caller must have checked [`Vme::can_issue`]. A zero-byte request
    /// completes immediately. Returns the request id *and* its delivery
    /// cycle, so the caller can schedule the completion into the event
    /// wheel at issue time (analytic FIFO service admits no preemption,
    /// so the time is exact, never an estimate).
    pub fn issue(&mut self, owner: Owner, bytes: u64, write: bool, now: u64) -> (ReqId, u64) {
        assert!(self.can_issue(now), "VME tag buffer full");
        let id = self.next_id;
        self.next_id += 1;
        self.counters.requests += 1;
        if bytes == 0 {
            self.completions.push(Completion { owner, id, at: now });
            return (id, now);
        }
        let beats = bytes.div_ceil(self.axi_bytes);
        let channel_free = if write { &mut self.write_free } else { &mut self.read_free };
        let start = (now + self.latency).max(*channel_free);
        let finish = start + beats;
        *channel_free = finish;
        if write {
            self.counters.bytes_written += bytes;
            self.counters.write_busy_cycles += beats;
        } else {
            self.counters.bytes_read += bytes;
            self.counters.read_busy_cycles += beats;
        }
        self.completions.push(Completion { owner, id, at: finish });
        (id, finish)
    }

    /// Advance one cycle — a no-op under analytic scheduling (kept for
    /// API stability with the beat-by-beat model).
    pub fn step(&mut self, _now: u64) {}

    /// Drain completions belonging to `owner` that have delivered by
    /// `now`.
    pub fn take_completed_at(&mut self, owner: Owner, now: u64) -> Vec<ReqId> {
        let mut out = Vec::new();
        self.completions.retain(|c| {
            if c.owner == owner && c.at <= now {
                out.push(c.id);
                false
            } else {
                true
            }
        });
        out
    }

    /// True when no requests are pending delivery or collection.
    pub fn idle(&self) -> bool {
        self.completions.is_empty()
    }

    /// Earliest cycle at which this engine delivers something new
    /// (for event-skip fast-forwarding); `None` when idle.
    pub fn next_event(&self, now: u64) -> Option<u64> {
        self.completions.iter().map(|c| c.at.max(now + 1)).min()
    }

    /// Split a transfer into AXI-legal bursts.
    pub fn split_bursts(&self, total_bytes: u64) -> Vec<u64> {
        let max = MAX_BURST_BEATS * self.axi_bytes;
        let mut out = Vec::new();
        let mut left = total_bytes;
        while left > 0 {
            let b = left.min(max);
            out.push(b);
            left -= b;
        }
        if out.is_empty() {
            out.push(0);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive until the request completes; returns the completion cycle.
    fn run_until_done(vme: &mut Vme, owner: Owner, id: ReqId, limit: u64) -> Option<u64> {
        for now in 0..limit {
            if vme.take_completed_at(owner, now).contains(&id) {
                return Some(now);
            }
        }
        None
    }

    #[test]
    fn single_request_timing() {
        // 64 bytes over an 8-byte bus with latency 4: data beats occupy
        // cycles 4..12, fully delivered at cycle 12.
        let mut vme = Vme::new(8, 4, 4);
        let (id, fin) = vme.issue(Owner::Load, 64, false, 0);
        assert_eq!(fin, 12, "analytic finish time returned at issue");
        assert_eq!(run_until_done(&mut vme, Owner::Load, id, 64), Some(12));
    }

    #[test]
    fn latency_hidden_with_multiple_tags() {
        // Two 64-byte reads issued together: the second streams right
        // after the first — total = latency + 16 beats, not 2*(lat+8).
        let mut vme = Vme::new(8, 10, 4);
        let (a, fa) = vme.issue(Owner::Load, 64, false, 0);
        let (b, fb) = vme.issue(Owner::Load, 64, false, 0);
        let ta = run_until_done(&mut vme, Owner::Load, a, 128).unwrap();
        let tb = run_until_done(&mut vme, Owner::Load, b, 128).unwrap();
        assert_eq!((ta, tb), (fa, fb), "returned finish times are exact");
        assert_eq!(tb - ta, 8, "back-to-back streaming");
        assert!(tb < 2 * (10 + 8), "latency must be overlapped");
    }

    #[test]
    fn single_tag_blocks_second_issue() {
        let mut vme = Vme::new(8, 10, 1);
        vme.issue(Owner::Load, 64, false, 0);
        assert!(!vme.can_issue(0));
        // The tag frees once the burst has delivered (cycle 18).
        assert!(vme.can_issue(18));
    }

    #[test]
    fn read_and_write_channels_independent() {
        let mut vme = Vme::new(8, 0, 4);
        let (r, _) = vme.issue(Owner::Load, 32, false, 0);
        let (w, _) = vme.issue(Owner::Store, 32, true, 0);
        let tr = run_until_done(&mut vme, Owner::Load, r, 64).unwrap();
        let tw = run_until_done(&mut vme, Owner::Store, w, 64).unwrap();
        assert_eq!(tr, tw, "channels run in parallel");
    }

    #[test]
    fn fifo_service_order_within_channel() {
        let mut vme = Vme::new(8, 0, 4);
        let (first, _) = vme.issue(Owner::Fetch, 8, false, 0);
        let (second, _) = vme.issue(Owner::Load, 8, false, 0);
        let t1 = run_until_done(&mut vme, Owner::Fetch, first, 16).unwrap();
        let t2 = run_until_done(&mut vme, Owner::Load, second, 16).unwrap();
        assert!(t1 < t2, "FIFO arbitration: {t1} vs {t2}");
    }

    #[test]
    fn zero_byte_completes_immediately() {
        let mut vme = Vme::new(8, 5, 2);
        let (id, fin) = vme.issue(Owner::Compute, 0, false, 3);
        assert_eq!(fin, 3, "zero-byte requests complete at issue");
        assert_eq!(vme.take_completed_at(Owner::Compute, 3), vec![id]);
        assert!(vme.idle());
    }

    #[test]
    fn burst_splitting() {
        let vme = Vme::new(8, 0, 2);
        // max burst = 256*8 = 2048 bytes
        assert_eq!(vme.split_bursts(5000), vec![2048, 2048, 904]);
        assert_eq!(vme.split_bursts(0), vec![0]);
        assert_eq!(vme.split_bursts(8), vec![8]);
    }

    #[test]
    fn counters_track_bytes() {
        let mut vme = Vme::new(16, 0, 2);
        vme.issue(Owner::Load, 64, false, 0);
        vme.issue(Owner::Store, 32, true, 0);
        assert_eq!(vme.counters.bytes_read, 64);
        assert_eq!(vme.counters.bytes_written, 32);
        assert_eq!(vme.counters.requests, 2);
        assert_eq!(vme.counters.read_busy_cycles, 4);
        assert_eq!(vme.counters.write_busy_cycles, 2);
    }

    #[test]
    fn next_event_points_at_completion() {
        let mut vme = Vme::new(8, 4, 4);
        vme.issue(Owner::Load, 64, false, 0);
        assert_eq!(vme.next_event(0), Some(12));
        assert_eq!(vme.next_event(20), Some(21)); // undrained completion
    }
}
