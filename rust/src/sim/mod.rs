//! *tsim* — the cycle-accurate simulator of the VTA micro-architecture
//! (§II-A, enhanced per §IV-A).
//!
//! Models the load–compute–store machine at the level that determines the
//! paper's cycle counts:
//!
//! * **fetch** streams the instruction image from DRAM through the VME
//!   and dispatches one instruction per cycle into per-module command
//!   queues;
//! * **load / compute / store** execute concurrently, synchronized only
//!   by the four dependency-token queues;
//! * **GEMM** runs at II=1 when `gemm_pipelined` (the §IV-A1 enhancement)
//!   or II=4 as published; **ALU** at II=1 (immediate) / II=2 (two
//!   operand) when pipelined, else II=4/5;
//! * the **VME** serializes DRAM traffic at the configured AXI width with
//!   bounded outstanding requests (Fig 5/6);
//! * padding fill overlaps DMA (Fig 5).
//!
//! Functional effects are applied through the shared
//! [`CoreState`](crate::exec::CoreState) at instruction completion, in
//! simulated-time order — so a correctly synchronized program computes
//! bit-exactly what *fsim* computes, and a mis-synchronized one diverges
//! (which the trace tooling then localizes).
//!
//! The simulator event-skips idle stretches, so wall-clock cost scales
//! with activity, not cycles.

pub mod activity;
pub mod queues;
pub mod vme;
pub mod wheel;

use crate::config::VtaConfig;
use crate::exec::{CoreState, ExecCounters};
use crate::isa::{BufferId, Insn, Opcode};
use crate::mem::Dram;
use activity::{Activity, ActivityTrace, Module};
use queues::{CmdQueue, TokenQueue};
use std::collections::VecDeque;
use vme::{Owner, ReqId, Vme, VmeCounters};
use wheel::EventWheel;

/// Cycles without progress before declaring deadlock.
const DEADLOCK_LIMIT: u64 = 1_000_000;

/// GEMM pipeline depth (fill/flush overhead per instruction). Public:
/// the analytical sweep model (`crate::model`) mirrors this arithmetic.
pub const GEMM_PIPE_FILL: u64 = 4;
/// ALU pipeline depth.
pub const ALU_PIPE_FILL: u64 = 2;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    /// Waiting to start (no instruction in flight).
    Idle,
    /// Consuming required dependency tokens.
    PopDeps,
    /// Executing the instruction body.
    Run,
    /// Producing dependency tokens.
    PushDeps,
}

/// An in-flight DMA transfer owned by one module.
#[derive(Debug)]
struct DmaJob {
    bursts: Vec<u64>,
    next_burst: usize,
    outstanding: usize,
    /// Cycle at which concurrent pad fill finishes (Fig 5 overlap).
    pad_ready_at: u64,
}

impl DmaJob {
    fn done(&self, now: u64) -> bool {
        self.next_burst == self.bursts.len() && self.outstanding == 0 && now >= self.pad_ready_at
    }
}

/// Per-module stall/busy accounting (reported in [`PerfReport`]).
///
/// Stall counters measure *elapsed* cycles spent waiting on dependency
/// tokens (accounted when the wait resolves, so they stay exact under
/// event-skipped simulation). They are report-only: neither the layer
/// memo nor the sweep cache stores them, so their accounting is not
/// part of the [`SIM_SCHEMA_VERSION`](crate::memo::SIM_SCHEMA_VERSION)
/// contract.
#[derive(Debug, Default, Clone, Copy)]
pub struct ModuleStats {
    pub busy_cycles: u64,
    /// Cycles spent blocked waiting to consume a dependency token.
    pub stall_pop_cycles: u64,
    /// Cycles spent blocked waiting to deposit a token into a full queue.
    pub stall_push_cycles: u64,
    pub insns: u64,
}

#[derive(Debug)]
struct Driver {
    phase: Phase,
    current: Option<Insn>,
    // Remaining dependency actions for the current instruction.
    need_pop_prev: bool,
    need_pop_next: bool,
    need_push_prev: bool,
    need_push_next: bool,
    // Run state.
    busy_until: u64,
    started_at: u64,
    /// Cycle at which the current PopDeps/PushDeps wait began; the
    /// elapsed span is charged to the stall counters when it resolves.
    wait_from: u64,
    dma: Option<DmaJob>,
    stats: ModuleStats,
}

impl Driver {
    fn new() -> Driver {
        Driver {
            phase: Phase::Idle,
            current: None,
            need_pop_prev: false,
            need_pop_next: false,
            need_push_prev: false,
            need_push_next: false,
            busy_until: 0,
            started_at: 0,
            wait_from: 0,
            dma: None,
            stats: ModuleStats::default(),
        }
    }

    fn idle(&self) -> bool {
        self.phase == Phase::Idle && self.current.is_none()
    }
}

/// Simulation result for one program (plus cumulative counters).
#[derive(Debug, Clone)]
pub struct PerfReport {
    pub cycles: u64,
    pub exec: ExecCounters,
    pub vme: VmeCounters,
    pub load: ModuleStats,
    pub compute: ModuleStats,
    pub store: ModuleStats,
    pub gemm_cycles: u64,
    pub alu_cycles: u64,
    /// Cycles the compute module spent on its own DMA (uop/acc loads).
    pub compute_dma_cycles: u64,
}

impl PerfReport {
    /// Achieved MACs per cycle — the y-axis of the roofline chart.
    pub fn macs_per_cycle(&self) -> f64 {
        self.exec.macs as f64 / self.cycles.max(1) as f64
    }

    /// Operational intensity in MACs per DRAM byte — roofline x-axis.
    pub fn macs_per_byte(&self) -> f64 {
        self.exec.macs as f64 / self.exec.dram_bytes_total().max(1) as f64
    }
}

pub struct Tsim {
    pub cfg: VtaConfig,
    pub core: CoreState,
    pub trace: ActivityTrace,
    cycle: u64,
    // Fetch state.
    program: Vec<Insn>,
    fetch_pos: usize,
    fetch_chunks: VecDeque<(ReqId, std::ops::Range<usize>, bool)>,
    fetched: VecDeque<Insn>,
    // Queues.
    load_q: CmdQueue,
    compute_q: CmdQueue,
    store_q: CmdQueue,
    ld2cmp: TokenQueue,
    cmp2ld: TokenQueue,
    cmp2st: TokenQueue,
    st2cmp: TokenQueue,
    // Modules.
    load: Driver,
    compute: Driver,
    store: Driver,
    vme: Vme,
    /// Pending pure-time wake events (VME finishes, pad fills,
    /// `busy_until`s), maintained incrementally by the drivers.
    wheel: EventWheel,
    /// Use the retained linear condition scan instead of the wheel —
    /// the reference implementation the differential fuzz suite
    /// compares against. Timeline-identical, just slower.
    linear_scan: bool,
    done: bool,
    last_progress: u64,
    gemm_cycles: u64,
    alu_cycles: u64,
    compute_dma_cycles: u64,
}

impl Tsim {
    pub fn new(cfg: &VtaConfig) -> Tsim {
        Tsim::with_mode(cfg, false)
    }

    /// Timing-only tsim: the timing wheel runs exactly as usual (cycle
    /// counts are bit-identical — VTA timing never reads tensor data),
    /// but instruction completion skips all datapath effects. See
    /// [`CoreState::timing_only`]. The mode is fixed at construction —
    /// each tsim instance sits on exactly one rung of the engine's
    /// fidelity ladder.
    pub fn timing_only(cfg: &VtaConfig) -> Tsim {
        Tsim::with_mode(cfg, true)
    }

    fn with_mode(cfg: &VtaConfig, timing_only: bool) -> Tsim {
        let mut core = CoreState::new(cfg);
        core.timing_only = timing_only;
        Tsim {
            cfg: cfg.clone(),
            core,
            trace: ActivityTrace::new(false),
            cycle: 0,
            program: Vec::new(),
            fetch_pos: 0,
            fetch_chunks: VecDeque::new(),
            fetched: VecDeque::new(),
            load_q: CmdQueue::new("load", cfg.cmd_queue_depth),
            compute_q: CmdQueue::new("compute", cfg.cmd_queue_depth),
            store_q: CmdQueue::new("store", cfg.cmd_queue_depth),
            ld2cmp: TokenQueue::new("ld->cmp", cfg.dep_queue_depth),
            cmp2ld: TokenQueue::new("cmp->ld", cfg.dep_queue_depth),
            cmp2st: TokenQueue::new("cmp->st", cfg.dep_queue_depth),
            st2cmp: TokenQueue::new("st->cmp", cfg.dep_queue_depth),
            load: Driver::new(),
            compute: Driver::new(),
            store: Driver::new(),
            vme: Vme::new(cfg.axi_bytes, cfg.dram_latency, cfg.vme_inflight),
            wheel: EventWheel::new(),
            linear_scan: false,
            done: false,
            last_progress: 0,
            gemm_cycles: 0,
            alu_cycles: 0,
            compute_dma_cycles: 0,
        }
    }

    pub fn enable_trace(&mut self) {
        self.trace.enabled = true;
    }

    /// Switch `advance_time` to the retained linear condition scan (the
    /// pre-wheel reference). Completion cycles, counters and digests are
    /// identical in both modes — asserted across random programs by
    /// `rust/tests/simd_event_parity.rs`; only wall-clock differs.
    pub fn set_linear_scan(&mut self, on: bool) {
        self.linear_scan = on;
    }

    /// Reset to the freshly-constructed state while keeping every
    /// allocation (scratchpads, queue storage) — the batched-evaluation
    /// fast path. Afterwards the simulator is indistinguishable from
    /// `Tsim::with_mode(&cfg, timing_only)` with the same trace-enable
    /// and scan-mode flags.
    pub fn reset_for_reuse(&mut self) {
        self.core.reset();
        self.trace = ActivityTrace::new(self.trace.enabled);
        self.cycle = 0;
        self.program.clear();
        self.fetch_pos = 0;
        self.fetch_chunks.clear();
        self.fetched.clear();
        self.load_q = CmdQueue::new("load", self.cfg.cmd_queue_depth);
        self.compute_q = CmdQueue::new("compute", self.cfg.cmd_queue_depth);
        self.store_q = CmdQueue::new("store", self.cfg.cmd_queue_depth);
        self.ld2cmp = TokenQueue::new("ld->cmp", self.cfg.dep_queue_depth);
        self.cmp2ld = TokenQueue::new("cmp->ld", self.cfg.dep_queue_depth);
        self.cmp2st = TokenQueue::new("cmp->st", self.cfg.dep_queue_depth);
        self.st2cmp = TokenQueue::new("st->cmp", self.cfg.dep_queue_depth);
        self.load = Driver::new();
        self.compute = Driver::new();
        self.store = Driver::new();
        self.vme = Vme::new(self.cfg.axi_bytes, self.cfg.dram_latency, self.cfg.vme_inflight);
        self.wheel.clear();
        self.done = false;
        self.last_progress = 0;
        self.gemm_cycles = 0;
        self.alu_cycles = 0;
        self.compute_dma_cycles = 0;
    }

    pub fn cycle(&self) -> u64 {
        self.cycle
    }

    /// Run one program (one layer's instruction stream, terminated by
    /// FINISH) to completion. The cycle counter and architectural state
    /// persist across calls, matching how the runtime launches one kernel
    /// per layer. Returns cycles consumed by this program.
    pub fn run(&mut self, insns: &[Insn], dram: &mut Dram, label: &str) -> u64 {
        assert!(
            insns.last().map(|i| i.opcode() == Opcode::Finish).unwrap_or(false),
            "program must end with FINISH"
        );
        let start_cycle = self.cycle;
        self.program = insns.to_vec();
        self.fetch_pos = 0;
        self.fetch_chunks.clear();
        self.fetched.clear();
        // The previous program drained completely (the loop below exits
        // only when every module, queue and the VME are idle), so no
        // valid wake can be pending — clear any stale ones.
        self.wheel.clear();
        self.done = false;
        self.last_progress = self.cycle;
        loop {
            self.step(dram);
            if self.done
                && self.load.idle()
                && self.compute.idle()
                && self.store.idle()
                && self.vme.idle()
                && self.fetched.is_empty()
                && self.load_q.is_empty()
                && self.compute_q.is_empty()
                && self.store_q.is_empty()
            {
                break;
            }
            if self.cycle - self.last_progress > DEADLOCK_LIMIT {
                panic!("tsim deadlock detected:\n{}", self.state_dump());
            }
            self.advance_time();
        }
        self.trace.mark(self.cycle, label);
        self.cycle - start_cycle
    }

    /// Jump to the next cycle at which anything can happen (event skip).
    ///
    /// Every enablement in the machine is one of two kinds: (a) *chained*
    /// — caused by a state change (progress) in the current cycle, e.g. a
    /// token push unblocking a pop, queue space freeing, a delivered
    /// fetch chunk enabling dispatch; or (b) *pure-time* — a threshold
    /// known at creation time (a VME burst finish, a pad-fill
    /// completion, a compute `busy_until`), which the drivers schedule
    /// into the wheel at the moment they compute it. So: after a
    /// progress cycle, wake at `now + 1` (the chained case); otherwise
    /// only a scheduled event can unblock anything, and the wheel knows
    /// the earliest one. Spurious wakes are no-op cycles (all conditions
    /// are level-triggered and re-checked), so the timeline is identical
    /// to the exhaustive linear scan — which is retained below as
    /// [`Tsim::advance_time_linear`] for differential testing.
    fn advance_time(&mut self) {
        if self.linear_scan {
            self.advance_time_linear();
            return;
        }
        let now = self.cycle;
        self.cycle = if self.last_progress == now {
            now + 1
        } else {
            // An empty wheel with no progress is a deadlock: grind one
            // cycle at a time so the limit counter trips, exactly as the
            // linear scan did.
            self.wheel.next_after(now).unwrap_or(now + 1)
        };
    }

    /// The pre-wheel exhaustive condition scan, kept as the reference
    /// implementation for `rust/tests/simd_event_parity.rs` (enable via
    /// [`Tsim::set_linear_scan`]). Note its fetch terms wake every cycle
    /// while any instruction is in flight — conservative (extra wakes
    /// are no-ops) but it defeats event-skip; the wheel path derives
    /// fetch wakes precisely from chunk-delivery events and dispatch
    /// progress instead.
    fn advance_time_linear(&mut self) {
        let now = self.cycle;
        let mut next = u64::MAX;
        let mut consider = |t: u64| {
            if t > now && t < next {
                next = t;
            }
        };
        // Fetch can act next cycle if it has work and space.
        if self.fetch_has_work() {
            consider(now + 1);
        }
        if !self.fetched.is_empty() {
            consider(now + 1);
        }
        let queues = [
            (&self.load, &self.load_q, None, Some(&self.cmp2ld), None, Some(&self.ld2cmp)),
            (
                &self.compute,
                &self.compute_q,
                Some(&self.ld2cmp),
                Some(&self.st2cmp),
                Some(&self.cmp2ld),
                Some(&self.cmp2st),
            ),
            (&self.store, &self.store_q, Some(&self.cmp2st), None, Some(&self.st2cmp), None),
        ];
        for (drv, cmd_q, pop_prev_q, pop_next_q, push_prev_q, push_next_q) in queues {
            match drv.phase {
                Phase::Idle => {
                    if !cmd_q.is_empty() {
                        consider(now + 1);
                    }
                }
                Phase::PopDeps => {
                    // Runnable next cycle if a needed token is present.
                    let blocked_prev = drv.need_pop_prev
                        && pop_prev_q.map(|q| q.tokens() == 0).unwrap_or(false);
                    let blocked_next = drv.need_pop_next
                        && pop_next_q.map(|q| q.tokens() == 0).unwrap_or(false);
                    if !blocked_prev && !blocked_next {
                        consider(now + 1);
                    }
                }
                Phase::PushDeps => {
                    // Symmetric with PopDeps: runnable next cycle only if
                    // every still-needed push has queue space. A full
                    // token queue drains only when its consumer pops
                    // during a step; the advance_time that follows that
                    // step sees the space and schedules the retry — the
                    // push lands on the same cycle as the old per-cycle
                    // busy poll did, without waking every cycle in
                    // between (`sim::tests::push_backpressure_*`).
                    let blocked_prev = drv.need_push_prev
                        && push_prev_q.map(|q| !q.has_space()).unwrap_or(false);
                    let blocked_next = drv.need_push_next
                        && push_next_q.map(|q| !q.has_space()).unwrap_or(false);
                    if !blocked_prev && !blocked_next {
                        consider(now + 1);
                    }
                }
                Phase::Run => {
                    if let Some(job) = &drv.dma {
                        if job.next_burst < job.bursts.len() {
                            consider(now + 1);
                        } else {
                            consider(job.pad_ready_at.max(now + 1));
                        }
                    } else {
                        consider(drv.busy_until.max(now + 1));
                    }
                }
            }
        }
        if let Some(t) = self.vme.next_event(now) {
            consider(t);
        }
        if next == u64::MAX {
            next = now + 1; // nothing scheduled; deadlock counter will trip
        }
        self.cycle = next;
    }

    fn fetch_has_work(&self) -> bool {
        self.fetch_pos < self.program.len() || !self.fetch_chunks.is_empty()
    }

    fn progress(&mut self) {
        self.last_progress = self.cycle;
    }

    fn step(&mut self, dram: &mut Dram) {
        self.step_fetch();
        self.step_load(dram);
        self.step_compute(dram);
        self.step_store(dram);
        self.vme.step(self.cycle);
    }

    // ---- fetch ----

    fn step_fetch(&mut self) {
        let now = self.cycle;
        // Issue instruction-fetch DMA in chunks of 64 instructions.
        while self.fetch_pos < self.program.len()
            && self.fetch_chunks.len() < 4
            && self.vme.can_issue(now)
        {
            let end = (self.fetch_pos + 64).min(self.program.len());
            let bytes = ((end - self.fetch_pos) * crate::config::INSN_BYTES) as u64;
            let (id, fin) = self.vme.issue(Owner::Fetch, bytes, false, now);
            self.wheel.schedule(fin);
            self.fetch_chunks.push_back((id, self.fetch_pos..end, false));
            self.fetch_pos = end;
            self.progress();
        }
        // Mark completed chunks; deliver them in order.
        for id in self.vme.take_completed_at(Owner::Fetch, now) {
            for chunk in self.fetch_chunks.iter_mut() {
                if chunk.0 == id {
                    chunk.2 = true;
                }
            }
        }
        while self.fetch_chunks.front().map(|c| c.2).unwrap_or(false) {
            let (_, range, _) = self.fetch_chunks.pop_front().unwrap();
            for i in range {
                self.fetched.push_back(self.program[i]);
            }
            self.progress();
        }
        // Dispatch one instruction per cycle (decoder rate).
        if let Some(insn) = self.fetched.front().copied() {
            let target = match &insn {
                Insn::Mem(m) if m.opcode == Opcode::Load => match m.buffer {
                    BufferId::Inp | BufferId::Wgt => &mut self.load_q,
                    _ => &mut self.compute_q,
                },
                Insn::Mem(_) => &mut self.store_q,
                Insn::Gemm(_) | Insn::Alu(_) | Insn::Finish(_) => &mut self.compute_q,
            };
            if target.has_space() {
                target.push(insn);
                self.fetched.pop_front();
                self.progress();
            }
        }
    }

    // ---- load ----

    fn step_load(&mut self, dram: &mut Dram) {
        let now = self.cycle;
        // Collect DMA completions.
        let comps = self.vme.take_completed_at(Owner::Load, now);
        if !comps.is_empty() {
            if let Some(job) = &mut self.load.dma {
                job.outstanding -= comps.len();
            }
            self.progress();
        }
        if self.load.phase == Phase::Idle {
            if let Some(insn) = self.load_q.pop() {
                let deps = insn.deps();
                debug_assert!(
                    !deps.pop_prev && !deps.push_prev,
                    "load module has no prev-side queues"
                );
                self.load.current = Some(insn);
                self.load.need_pop_next = deps.pop_next;
                self.load.need_push_next = deps.push_next;
                self.load.phase = Phase::PopDeps;
                self.load.wait_from = now;
                self.progress();
            }
        }
        if self.load.phase == Phase::PopDeps {
            if self.load.need_pop_next {
                if self.cmp2ld.try_pop() {
                    self.load.need_pop_next = false;
                    self.progress();
                } else {
                    return;
                }
            }
            self.load.stats.stall_pop_cycles += now - self.load.wait_from;
            // Start the DMA.
            let insn = self.load.current.unwrap();
            let m = match insn {
                Insn::Mem(m) => m,
                _ => unreachable!("load module only receives memory insns"),
            };
            let tile_bytes = self.core.tile_bytes(m.buffer) as u64;
            let mut bursts = Vec::new();
            // Residency-elided transfers occupy zero VME bandwidth: the
            // empty burst list makes the job complete via pad_ready_at
            // alone, while CoreState::execute still applies the data.
            if !self.core.transfer_elided(&m, tile_bytes as usize) {
                for _ in 0..m.y_size.max(1) {
                    if m.x_size > 0 {
                        bursts.extend(self.vme.split_bursts(m.x_size as u64 * tile_bytes));
                    }
                }
            }
            let pad_tiles = m.sram_tiles() - m.dram_tiles();
            self.wheel.schedule(now + pad_tiles);
            self.load.dma = Some(DmaJob {
                bursts,
                next_burst: 0,
                outstanding: 0,
                pad_ready_at: now + pad_tiles,
            });
            self.load.started_at = now;
            self.load.phase = Phase::Run;
            self.progress();
        }
        if self.load.phase == Phase::Run {
            let job = self.load.dma.as_mut().unwrap();
            while job.next_burst < job.bursts.len() && self.vme.can_issue(now) {
                let bytes = job.bursts[job.next_burst];
                let (_, fin) = self.vme.issue(Owner::Load, bytes, false, now);
                self.wheel.schedule(fin);
                job.next_burst += 1;
                job.outstanding += 1;
                self.last_progress = now;
            }
            if job.done(now) {
                let insn = self.load.current.unwrap();
                self.core.execute(&insn, dram);
                self.load.dma = None;
                let end = now.max(self.load.started_at + 1);
                self.trace.record(Module::Load, Activity::LoadDma, self.load.started_at, end);
                self.load.stats.busy_cycles += end - self.load.started_at;
                self.load.stats.insns += 1;
                self.load.phase = Phase::PushDeps;
                self.load.wait_from = now;
                self.progress();
            }
        }
        if self.load.phase == Phase::PushDeps {
            if self.load.need_push_next {
                if self.ld2cmp.try_push() {
                    self.load.need_push_next = false;
                    self.progress();
                } else {
                    return;
                }
            }
            self.load.stats.stall_push_cycles += now - self.load.wait_from;
            self.load.current = None;
            self.load.phase = Phase::Idle;
        }
    }

    // ---- compute ----

    fn step_compute(&mut self, dram: &mut Dram) {
        let now = self.cycle;
        let comps = self.vme.take_completed_at(Owner::Compute, now);
        if !comps.is_empty() {
            if let Some(job) = &mut self.compute.dma {
                job.outstanding -= comps.len();
            }
            self.progress();
        }
        if self.compute.phase == Phase::Idle {
            if let Some(insn) = self.compute_q.pop() {
                let deps = insn.deps();
                self.compute.current = Some(insn);
                self.compute.need_pop_prev = deps.pop_prev;
                self.compute.need_pop_next = deps.pop_next;
                self.compute.need_push_prev = deps.push_prev;
                self.compute.need_push_next = deps.push_next;
                self.compute.phase = Phase::PopDeps;
                self.compute.wait_from = now;
                self.progress();
            }
        }
        if self.compute.phase == Phase::PopDeps {
            if self.compute.need_pop_prev {
                if self.ld2cmp.try_pop() {
                    self.compute.need_pop_prev = false;
                    self.progress();
                } else {
                    return;
                }
            }
            if self.compute.need_pop_next {
                if self.st2cmp.try_pop() {
                    self.compute.need_pop_next = false;
                    self.progress();
                } else {
                    return;
                }
            }
            self.compute.stats.stall_pop_cycles += now - self.compute.wait_from;
            // Begin execution.
            let insn = self.compute.current.unwrap();
            self.compute.started_at = now;
            match &insn {
                Insn::Gemm(g) => {
                    let ii = if self.cfg.gemm_pipelined { 1 } else { 4 };
                    self.compute.busy_until = now + GEMM_PIPE_FILL + g.total_ops() * ii;
                    self.wheel.schedule(self.compute.busy_until);
                }
                Insn::Alu(a) => {
                    let ii = match (self.cfg.alu_pipelined, a.use_imm) {
                        (true, true) => 1,
                        (true, false) => 2,
                        (false, true) => 4,
                        (false, false) => 5,
                    };
                    let beats = a.total_ops() * self.cfg.batch as u64;
                    self.compute.busy_until = now + ALU_PIPE_FILL + beats * ii;
                    self.wheel.schedule(self.compute.busy_until);
                }
                Insn::Mem(m) => {
                    debug_assert_eq!(m.opcode, Opcode::Load);
                    let tile_bytes = self.core.tile_bytes(m.buffer) as u64;
                    let mut bursts = Vec::new();
                    // Elided acc/uop-side loads: zero DMA, data applied
                    // at completion as always.
                    if !self.core.transfer_elided(m, tile_bytes as usize) {
                        for _ in 0..m.y_size.max(1) {
                            if m.x_size > 0 {
                                bursts.extend(self.vme.split_bursts(m.x_size as u64 * tile_bytes));
                            }
                        }
                    }
                    let pad_tiles = m.sram_tiles() - m.dram_tiles();
                    self.wheel.schedule(now + pad_tiles);
                    self.compute.dma = Some(DmaJob {
                        bursts,
                        next_burst: 0,
                        outstanding: 0,
                        pad_ready_at: now + pad_tiles,
                    });
                }
                Insn::Finish(_) => {
                    self.compute.busy_until = now + 1;
                    self.wheel.schedule(self.compute.busy_until);
                }
            }
            self.compute.phase = Phase::Run;
            self.progress();
        }
        if self.compute.phase == Phase::Run {
            let insn = self.compute.current.unwrap();
            let finished = if let Some(job) = self.compute.dma.as_mut() {
                while job.next_burst < job.bursts.len() && self.vme.can_issue(now) {
                    let bytes = job.bursts[job.next_burst];
                    let (_, fin) = self.vme.issue(Owner::Compute, bytes, false, now);
                    self.wheel.schedule(fin);
                    job.next_burst += 1;
                    job.outstanding += 1;
                    self.last_progress = now;
                }
                job.done(now)
            } else {
                now >= self.compute.busy_until
            };
            if finished {
                self.core.execute(&insn, dram);
                self.compute.dma = None;
                let end = now.max(self.compute.started_at + 1);
                let dur = end - self.compute.started_at;
                let activity = match &insn {
                    Insn::Gemm(_) => {
                        self.gemm_cycles += dur;
                        Activity::Gemm
                    }
                    Insn::Alu(_) => {
                        self.alu_cycles += dur;
                        Activity::Alu
                    }
                    Insn::Mem(m) => {
                        self.compute_dma_cycles += dur;
                        if m.buffer == BufferId::Uop {
                            Activity::LoadUop
                        } else {
                            Activity::LoadAcc
                        }
                    }
                    Insn::Finish(_) => Activity::Gemm, // negligible; not traced
                };
                if !matches!(insn, Insn::Finish(_)) {
                    self.trace.record(Module::Compute, activity, self.compute.started_at, end);
                }
                self.compute.stats.busy_cycles += dur;
                self.compute.stats.insns += 1;
                self.compute.phase = Phase::PushDeps;
                self.compute.wait_from = now;
                self.progress();
            }
        }
        if self.compute.phase == Phase::PushDeps {
            if self.compute.need_push_prev {
                if self.cmp2ld.try_push() {
                    self.compute.need_push_prev = false;
                    self.progress();
                } else {
                    return;
                }
            }
            if self.compute.need_push_next {
                if self.cmp2st.try_push() {
                    self.compute.need_push_next = false;
                    self.progress();
                } else {
                    return;
                }
            }
            self.compute.stats.stall_push_cycles += now - self.compute.wait_from;
            if matches!(self.compute.current, Some(Insn::Finish(_))) {
                self.done = true;
            }
            self.compute.current = None;
            self.compute.phase = Phase::Idle;
        }
    }

    // ---- store ----

    fn step_store(&mut self, dram: &mut Dram) {
        let now = self.cycle;
        let comps = self.vme.take_completed_at(Owner::Store, now);
        if !comps.is_empty() {
            if let Some(job) = &mut self.store.dma {
                job.outstanding -= comps.len();
            }
            self.progress();
        }
        if self.store.phase == Phase::Idle {
            if let Some(insn) = self.store_q.pop() {
                let deps = insn.deps();
                debug_assert!(
                    !deps.pop_next && !deps.push_next,
                    "store module has no next-side queues"
                );
                self.store.current = Some(insn);
                self.store.need_pop_prev = deps.pop_prev;
                self.store.need_push_prev = deps.push_prev;
                self.store.phase = Phase::PopDeps;
                self.store.wait_from = now;
                self.progress();
            }
        }
        if self.store.phase == Phase::PopDeps {
            if self.store.need_pop_prev {
                if self.cmp2st.try_pop() {
                    self.store.need_pop_prev = false;
                    self.progress();
                } else {
                    return;
                }
            }
            self.store.stats.stall_pop_cycles += now - self.store.wait_from;
            let insn = self.store.current.unwrap();
            let m = match insn {
                Insn::Mem(m) => m,
                _ => unreachable!("store module only receives memory insns"),
            };
            // Store reads OUT scratchpad and writes DRAM: apply the
            // functional effect at completion, but the data must be
            // snapshotted now. Since dependency tokens guarantee the OUT
            // region is stable until we push_prev, applying at completion
            // is equivalent.
            let tile_bytes = self.core.tile_bytes(m.buffer) as u64;
            let mut bursts = Vec::new();
            // Elided stores (write-through to a resident consumer) skip
            // the DMA; the functional DRAM write still happens below.
            if !self.core.transfer_elided(&m, tile_bytes as usize) {
                for _ in 0..m.y_size.max(1) {
                    if m.x_size > 0 {
                        bursts.extend(self.vme.split_bursts(m.x_size as u64 * tile_bytes));
                    }
                }
            }
            // No pad fill on stores: pad_ready_at == now needs no wake.
            self.store.dma = Some(DmaJob {
                bursts,
                next_burst: 0,
                outstanding: 0,
                pad_ready_at: now,
            });
            self.store.started_at = now;
            self.store.phase = Phase::Run;
            self.progress();
        }
        if self.store.phase == Phase::Run {
            let job = self.store.dma.as_mut().unwrap();
            while job.next_burst < job.bursts.len() && self.vme.can_issue(now) {
                let bytes = job.bursts[job.next_burst];
                let (_, fin) = self.vme.issue(Owner::Store, bytes, true, now);
                self.wheel.schedule(fin);
                job.next_burst += 1;
                job.outstanding += 1;
                self.last_progress = now;
            }
            if job.done(now) {
                let insn = self.store.current.unwrap();
                self.core.execute(&insn, dram);
                self.store.dma = None;
                let end = now.max(self.store.started_at + 1);
                self.trace.record(Module::Store, Activity::StoreDma, self.store.started_at, end);
                self.store.stats.busy_cycles += end - self.store.started_at;
                self.store.stats.insns += 1;
                self.store.phase = Phase::PushDeps;
                self.store.wait_from = now;
                self.progress();
            }
        }
        if self.store.phase == Phase::PushDeps {
            if self.store.need_push_prev {
                if self.st2cmp.try_push() {
                    self.store.need_push_prev = false;
                    self.progress();
                } else {
                    return;
                }
            }
            self.store.stats.stall_push_cycles += now - self.store.wait_from;
            self.store.current = None;
            self.store.phase = Phase::Idle;
        }
    }

    pub fn report(&self) -> PerfReport {
        PerfReport {
            cycles: self.cycle,
            exec: self.core.counters,
            vme: self.vme.counters,
            load: self.load.stats,
            compute: self.compute.stats,
            store: self.store.stats,
            gemm_cycles: self.gemm_cycles,
            alu_cycles: self.alu_cycles,
            compute_dma_cycles: self.compute_dma_cycles,
        }
    }

    fn state_dump(&self) -> String {
        format!(
            "cycle={} done={}\n\
             queues: load={} compute={} store={} fetched={}\n\
             tokens: ld->cmp={} cmp->ld={} cmp->st={} st->cmp={}\n\
             load: {:?} current={:?}\n\
             compute: {:?} current={:?}\n\
             store: {:?} current={:?}",
            self.cycle,
            self.done,
            self.load_q.len(),
            self.compute_q.len(),
            self.store_q.len(),
            self.fetched.len(),
            self.ld2cmp.tokens(),
            self.cmp2ld.tokens(),
            self.cmp2st.tokens(),
            self.st2cmp.tokens(),
            self.load.phase,
            self.load.current.map(|i| i.disasm()),
            self.compute.phase,
            self.compute.current.map(|i| i.disasm()),
            self.store.phase,
            self.store.current.map(|i| i.disasm()),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;
    use crate::fsim::Fsim;
    use crate::isa::{AluInsn, AluOp, DepFlags, GemmInsn, MemInsn, Uop};
    use crate::util::rng::Pcg32;

    /// Hand-built single-tile program: load uops+inp+wgt, GEMM, CLIP,
    /// store, FINISH — with full dependency tokens.
    fn tile_program(st: &CoreState, dram: &mut Dram, rng: &mut Pcg32) -> (Vec<Insn>, Vec<i8>, crate::mem::DramRegion) {
        let cfg = &st.cfg;
        let l = &st.layout;
        let inp = rng.i8_vec(cfg.inp_tile_elems());
        let wgt = rng.i8_vec(cfg.wgt_tile_elems());
        let ri = dram.alloc(cfg.inp_tile_bytes(), cfg.inp_tile_bytes());
        let rw = dram.alloc(cfg.wgt_tile_bytes(), cfg.wgt_tile_bytes());
        dram.write_i8(ri, &inp);
        dram.write_i8(rw, &wgt);
        let uops = vec![Uop::gemm(0, 0, 0)];
        let ub = Uop::stream_to_bytes(&uops, l);
        let ru = dram.alloc(ub.len(), l.uop_bytes());
        dram.write(ru.addr, &ub);
        let rout = dram.alloc(cfg.out_tile_bytes(), cfg.out_tile_bytes());

        let mem = |buffer, sram, dram_base, deps| {
            Insn::Mem(MemInsn {
                opcode: Opcode::Load,
                deps,
                buffer,
                sram_base: sram,
                dram_base,
                y_size: 1,
                x_size: 1,
                x_stride: 1,
                y_pad0: 0,
                y_pad1: 0,
                x_pad0: 0,
                x_pad1: 0,
                pad_value: 0,
            })
        };
        let insns = vec![
            mem(BufferId::Uop, 0, ru.tile_base(l.uop_bytes()), DepFlags::NONE),
            // loads by the load module, signalling compute
            mem(BufferId::Inp, 0, ri.tile_base(cfg.inp_tile_bytes()), DepFlags::NONE),
            mem(BufferId::Wgt, 0, rw.tile_base(cfg.wgt_tile_bytes()), DepFlags::NONE.push_next()),
            Insn::Gemm(GemmInsn {
                deps: DepFlags::NONE.pop_prev(),
                reset: false,
                uop_bgn: 0,
                uop_end: 1,
                lp_out: 1,
                lp_in: 1,
                acc_f0: 0,
                acc_f1: 0,
                inp_f0: 0,
                inp_f1: 0,
                wgt_f0: 0,
                wgt_f1: 0,
            }),
            Insn::Alu(AluInsn {
                deps: DepFlags::NONE.push_next(),
                reset: false,
                op: AluOp::Clip,
                uop_bgn: 0,
                uop_end: 1,
                lp_out: 1,
                lp_in: 1,
                dst_f0: 0,
                dst_f1: 0,
                src_f0: 0,
                src_f1: 0,
                use_imm: true,
                imm: 127,
            }),
            Insn::Mem(MemInsn {
                opcode: Opcode::Store,
                deps: DepFlags::NONE.pop_prev().push_prev(),
                buffer: BufferId::Out,
                sram_base: 0,
                dram_base: rout.tile_base(cfg.out_tile_bytes()),
                y_size: 1,
                x_size: 1,
                x_stride: 1,
                y_pad0: 0,
                y_pad1: 0,
                x_pad0: 0,
                x_pad1: 0,
                pad_value: 0,
            }),
            Insn::Finish(DepFlags::NONE),
        ];
        // Expected: clip(inp · wgtᵀ, ±127) per element.
        let mut expect = Vec::new();
        for b in 0..cfg.batch {
            for o in 0..cfg.block_out {
                let s: i32 = (0..cfg.block_in)
                    .map(|i| inp[b * cfg.block_in + i] as i32 * wgt[o * cfg.block_in + i] as i32)
                    .sum();
                expect.push(s.clamp(-127, 127) as i8);
            }
        }
        (insns, expect, rout)
    }

    #[test]
    fn tsim_runs_tile_program_correctly() {
        let cfg = presets::tiny_config();
        let mut dram = Dram::new(1 << 20);
        let mut rng = Pcg32::seeded(42);
        let mut sim = Tsim::new(&cfg);
        let (insns, expect, rout) = tile_program(&sim.core, &mut dram, &mut rng);
        let cycles = sim.run(&insns, &mut dram, "tile");
        assert!(cycles > 0);
        assert_eq!(dram.read_i8(rout), expect);
    }

    #[test]
    fn tsim_matches_fsim_bit_exactly() {
        let cfg = presets::tiny_config();
        let mut rng = Pcg32::seeded(7);
        let mut dram_t = Dram::new(1 << 20);
        let mut tsim = Tsim::new(&cfg);
        let (insns, _, rout) = tile_program(&tsim.core, &mut dram_t, &mut rng);
        tsim.run(&insns, &mut dram_t, "t");

        let mut rng = Pcg32::seeded(7);
        let mut dram_f = Dram::new(1 << 20);
        let mut fsim = Fsim::new(&cfg);
        let (insns_f, _, rout_f) = tile_program(&fsim.state, &mut dram_f, &mut rng);
        fsim.run(&insns_f, &mut dram_f);

        assert_eq!(dram_t.read_i8(rout), dram_f.read_i8(rout_f));
        for b in crate::isa::BufferId::ALL {
            assert_eq!(
                tsim.core.buffer_digest(b),
                fsim.state.buffer_digest(b),
                "digest mismatch on {b:?}"
            );
        }
    }

    #[test]
    fn pipelining_reduces_cycles() {
        let mut rng = Pcg32::seeded(3);
        let mut cfg_fast = presets::tiny_config();
        cfg_fast.gemm_pipelined = true;
        cfg_fast.alu_pipelined = true;
        let mut cfg_slow = cfg_fast.clone();
        cfg_slow.gemm_pipelined = false;
        cfg_slow.alu_pipelined = false;

        // A bigger GEMM so compute dominates: 64 uops, 8x8 loops.
        let build = |st: &CoreState, dram: &mut Dram, rng: &mut Pcg32| {
            let (mut insns, _, _) = tile_program(st, dram, rng);
            if let Insn::Gemm(g) = &mut insns[3] {
                g.lp_out = 8;
                g.lp_in = 8;
            }
            insns
        };
        let mut dram = Dram::new(1 << 20);
        let mut fast = Tsim::new(&cfg_fast);
        let insns = build(&fast.core, &mut dram, &mut rng);
        let fast_cycles = fast.run(&insns, &mut dram, "fast");

        let mut rng = Pcg32::seeded(3);
        let mut dram = Dram::new(1 << 20);
        let mut slow = Tsim::new(&cfg_slow);
        let insns = build(&slow.core, &mut dram, &mut rng);
        let slow_cycles = slow.run(&insns, &mut dram, "slow");

        assert!(
            slow_cycles as f64 > fast_cycles as f64 * 1.5,
            "expected pipelining speedup, fast={fast_cycles} slow={slow_cycles}"
        );
    }

    #[test]
    fn wider_axi_speeds_up_loads() {
        let mut rng = Pcg32::seeded(5);
        let mut narrow = presets::tiny_config();
        narrow.axi_bytes = 8;
        let mut wide = narrow.clone();
        wide.axi_bytes = 64;
        wide.name = "wide".into();

        // Load-heavy program: several weight loads.
        let build = |st: &CoreState, dram: &mut Dram, rng: &mut Pcg32| {
            let cfg = st.cfg.clone();
            let n = 16;
            let data = rng.i8_vec(n * cfg.wgt_tile_bytes());
            let r = dram.alloc(data.len(), cfg.wgt_tile_bytes());
            dram.write_i8(r, &data);
            let mut insns = vec![];
            for _ in 0..4 {
                insns.push(Insn::Mem(MemInsn {
                    opcode: Opcode::Load,
                    deps: DepFlags::NONE,
                    buffer: BufferId::Wgt,
                    sram_base: 0,
                    dram_base: r.tile_base(cfg.wgt_tile_bytes()),
                    y_size: 1,
                    x_size: n as u32,
                    x_stride: n as u32,
                    y_pad0: 0,
                    y_pad1: 0,
                    x_pad0: 0,
                    x_pad1: 0,
                    pad_value: 0,
                }));
            }
            insns.push(Insn::Finish(DepFlags::NONE));
            insns
        };
        let mut dram = Dram::new(1 << 20);
        let mut sim_n = Tsim::new(&narrow);
        let insns = build(&sim_n.core, &mut dram, &mut rng);
        let slow = sim_n.run(&insns, &mut dram, "n");

        let mut rng = Pcg32::seeded(5);
        let mut dram = Dram::new(1 << 20);
        let mut sim_w = Tsim::new(&wide);
        let insns = build(&sim_w.core, &mut dram, &mut rng);
        let fastc = sim_w.run(&insns, &mut dram, "w");
        assert!(slow > fastc * 2, "axi width should matter: narrow={slow} wide={fastc}");
    }

    #[test]
    #[should_panic(expected = "deadlock")]
    fn missing_token_deadlocks() {
        let cfg = presets::tiny_config();
        let mut dram = Dram::new(1 << 20);
        let mut sim = Tsim::new(&cfg);
        // GEMM pops a token that nothing pushes.
        let insns = vec![
            Insn::Gemm(GemmInsn {
                deps: DepFlags::NONE.pop_prev(),
                reset: true,
                uop_bgn: 0,
                uop_end: 1,
                lp_out: 1,
                lp_in: 1,
                acc_f0: 0,
                acc_f1: 0,
                inp_f0: 0,
                inp_f1: 0,
                wgt_f0: 0,
                wgt_f1: 0,
            }),
            Insn::Finish(DepFlags::NONE),
        ];
        sim.run(&insns, &mut dram, "dead");
    }

    #[test]
    fn load_and_compute_overlap_with_tokens() {
        // Two independent halves: load(h2) runs while compute(h1) runs.
        // Verified by checking the activity trace for overlap.
        let cfg = presets::tiny_config();
        let mut rng = Pcg32::seeded(9);
        let mut dram = Dram::new(1 << 20);
        let mut sim = Tsim::new(&cfg);
        sim.enable_trace();
        let (mut insns, _, _) = tile_program(&sim.core, &mut dram, &mut rng);
        // Enlarge GEMM so it takes a while.
        if let Insn::Gemm(g) = &mut insns[3] {
            g.lp_out = 16;
            g.lp_in = 16;
        }
        // Append an independent (token-free) load of a different region.
        insns.insert(
            4,
            Insn::Mem(MemInsn {
                opcode: Opcode::Load,
                deps: DepFlags::NONE,
                buffer: BufferId::Inp,
                sram_base: 1,
                dram_base: 0,
                y_size: 1,
                x_size: 8,
                x_stride: 8,
                y_pad0: 0,
                y_pad1: 0,
                x_pad0: 0,
                x_pad1: 0,
                pad_value: 0,
            }),
        );
        sim.run(&insns, &mut dram, "overlap");
        let gemm = sim
            .trace
            .intervals
            .iter()
            .find(|iv| iv.activity == Activity::Gemm)
            .copied()
            .unwrap();
        let second_load = sim
            .trace
            .intervals
            .iter()
            .filter(|iv| iv.module == Module::Load)
            .last()
            .copied()
            .unwrap();
        assert!(
            second_load.start < gemm.end && gemm.start < second_load.end,
            "load {second_load:?} should overlap gemm {gemm:?}"
        );
    }

    /// A producer blocked on a full token queue must be rescheduled at
    /// the consumer's next pop, not busy-polled: the program completes,
    /// deterministically, and deeper queues can only help. (The old
    /// `advance_time` woke every cycle while a push was blocked; the
    /// event-driven retry lands the push on the same cycle — asserted
    /// indirectly by the unchanged `pipelining_reduces_cycles` /
    /// `wider_axi_speeds_up_loads` cycle relations above.)
    #[test]
    fn push_backpressure_completes_and_only_slows() {
        let build = |st: &CoreState, dram: &mut Dram| -> Vec<Insn> {
            let cfg = st.cfg.clone();
            let l = &st.layout;
            let uops = vec![Uop::gemm(0, 0, 0)];
            let ub = Uop::stream_to_bytes(&uops, l);
            let ru = dram.alloc(ub.len(), l.uop_bytes());
            dram.write(ru.addr, &ub);
            let n = 8usize;
            let r = dram.alloc(n * cfg.wgt_tile_bytes(), cfg.wgt_tile_bytes());
            let wgt_load = |deps| {
                Insn::Mem(MemInsn {
                    opcode: Opcode::Load,
                    deps,
                    buffer: BufferId::Wgt,
                    sram_base: 0,
                    dram_base: r.tile_base(cfg.wgt_tile_bytes()),
                    y_size: 1,
                    x_size: n as u32,
                    x_stride: n as u32,
                    y_pad0: 0,
                    y_pad1: 0,
                    x_pad0: 0,
                    x_pad1: 0,
                    pad_value: 0,
                })
            };
            let mut insns = vec![Insn::Mem(MemInsn {
                opcode: Opcode::Load,
                deps: DepFlags::NONE,
                buffer: BufferId::Uop,
                sram_base: 0,
                dram_base: ru.tile_base(l.uop_bytes()),
                y_size: 1,
                x_size: 1,
                x_stride: 1,
                y_pad0: 0,
                y_pad1: 0,
                x_pad0: 0,
                x_pad1: 0,
                pad_value: 0,
            })];
            // Fast producers: four loads, each pushing a token.
            for _ in 0..4 {
                insns.push(wgt_load(DepFlags::NONE.push_next()));
            }
            // Slow consumers: four long unpipelined reset-GEMMs, each
            // popping one token — the loads outrun them, so with a
            // depth-1 queue the third load's push blocks for thousands
            // of cycles.
            for _ in 0..4 {
                insns.push(Insn::Gemm(GemmInsn {
                    deps: DepFlags::NONE.pop_prev(),
                    reset: true,
                    uop_bgn: 0,
                    uop_end: 1,
                    lp_out: 64,
                    lp_in: 64,
                    acc_f0: 0,
                    acc_f1: 0,
                    inp_f0: 0,
                    inp_f1: 0,
                    wgt_f0: 0,
                    wgt_f1: 0,
                }));
            }
            insns.push(Insn::Finish(DepFlags::NONE));
            insns
        };
        let run_with_depth = |depth: usize| -> (u64, u64) {
            let mut cfg = presets::tiny_config();
            cfg.dep_queue_depth = depth;
            cfg.gemm_pipelined = false;
            let mut dram = Dram::new(1 << 20);
            let mut sim = Tsim::new(&cfg);
            let insns = build(&sim.core, &mut dram);
            let cycles = sim.run(&insns, &mut dram, "bp");
            assert_eq!(sim.ld2cmp.pushes, 4, "every blocked push must eventually land");
            assert_eq!(sim.ld2cmp.pops, 4);
            (cycles, sim.load.stats.stall_push_cycles)
        };
        let (shallow, shallow_stalls) = run_with_depth(1);
        let (shallow2, _) = run_with_depth(1);
        let (deep, _) = run_with_depth(32);
        assert_eq!(shallow, shallow2, "backpressured runs must be deterministic");
        assert!(shallow_stalls > 0, "the depth-1 queue must actually block a push");
        assert!(deep > 0);
        assert!(
            shallow >= deep,
            "a deeper token queue can only help: depth1={shallow} depth32={deep}"
        );
    }

    #[test]
    fn wheel_and_linear_scan_agree() {
        // The bucketed event core must be timeline-identical to the
        // exhaustive linear scan (the broad random sweep lives in
        // rust/tests/simd_event_parity.rs; this is the smoke version).
        let cfg = presets::tiny_config();
        let run_mode = |linear: bool| -> (u64, ExecCounters, u64) {
            let mut rng = Pcg32::seeded(13);
            let mut dram = Dram::new(1 << 20);
            let mut sim = Tsim::new(&cfg);
            sim.set_linear_scan(linear);
            let (insns, _, _) = tile_program(&sim.core, &mut dram, &mut rng);
            let cycles = sim.run(&insns, &mut dram, "mode");
            (cycles, sim.core.counters, sim.core.buffer_digest(BufferId::Out))
        };
        assert_eq!(run_mode(false), run_mode(true));
    }

    #[test]
    fn reset_for_reuse_is_bit_identical_to_fresh() {
        let cfg = presets::tiny_config();
        let mut rng = Pcg32::seeded(17);
        let mut dram = Dram::new(1 << 20);
        let mut sim = Tsim::new(&cfg);
        let (insns, _, rout) = tile_program(&sim.core, &mut dram, &mut rng);
        let fresh_cycles = sim.run(&insns, &mut dram, "a");
        let fresh_out = dram.read_i8(rout);
        let fresh_counters = sim.core.counters;
        // Same program on a reused simulator against identical DRAM.
        let mut rng = Pcg32::seeded(17);
        let mut dram2 = Dram::new(1 << 20);
        sim.reset_for_reuse();
        assert_eq!(sim.cycle(), 0);
        let (insns2, _, rout2) = tile_program(&sim.core, &mut dram2, &mut rng);
        let reused_cycles = sim.run(&insns2, &mut dram2, "b");
        assert_eq!(reused_cycles, fresh_cycles);
        assert_eq!(dram2.read_i8(rout2), fresh_out);
        assert_eq!(sim.core.counters, fresh_counters);
    }

    #[test]
    fn elided_transfers_cost_no_dma_cycles_and_keep_digests() {
        // The same program with the load/store DRAM span marked
        // resident must finish in strictly fewer cycles, with every
        // buffer digest bit-identical and the traffic redirected into
        // the elided counters.
        let cfg = presets::tiny_config();
        let run = |elide: bool| -> (u64, ExecCounters, Vec<u64>, Vec<i8>) {
            let mut rng = Pcg32::seeded(21);
            let mut dram = Dram::new(1 << 20);
            let mut sim = Tsim::new(&cfg);
            let (insns, _, rout) = tile_program(&sim.core, &mut dram, &mut rng);
            if elide {
                // Cover the whole DRAM arena: every load and the store
                // are resident-elided. Padding-only transfers (none
                // here) would be exempt via dram_tiles() == 0.
                sim.core.set_elided_ranges(vec![(0, 1 << 20)]);
            }
            let cycles = sim.run(&insns, &mut dram, "e");
            let digests: Vec<u64> =
                BufferId::ALL.iter().map(|&b| sim.core.buffer_digest(b)).collect();
            (cycles, sim.core.counters, digests, dram.read_i8(rout))
        };
        let (base_cycles, base_ctr, base_dig, base_out) = run(false);
        let (el_cycles, el_ctr, el_dig, el_out) = run(true);
        assert_eq!(base_dig, el_dig, "elision must not change any scratchpad");
        assert_eq!(base_out, el_out, "elision must not change DRAM results");
        assert!(
            el_cycles < base_cycles,
            "elided DMA must be strictly faster: {el_cycles} vs {base_cycles}"
        );
        assert_eq!(el_ctr.dram_bytes_total(), 0, "all traffic elided");
        assert_eq!(
            el_ctr.dma_bytes_elided,
            base_ctr.dram_bytes_total(),
            "every skipped byte must be accounted as elided"
        );
        assert_eq!(el_ctr.macs, base_ctr.macs);
    }

    #[test]
    fn report_counters_consistent() {
        let cfg = presets::tiny_config();
        let mut rng = Pcg32::seeded(1);
        let mut dram = Dram::new(1 << 20);
        let mut sim = Tsim::new(&cfg);
        let (insns, _, _) = tile_program(&sim.core, &mut dram, &mut rng);
        sim.run(&insns, &mut dram, "r");
        let rep = sim.report();
        assert_eq!(rep.exec.macs, cfg.macs_per_gemm_op() as u64);
        assert!(rep.vme.bytes_read > 0);
        assert!(rep.vme.bytes_written >= cfg.out_tile_bytes() as u64);
        assert!(rep.compute.insns >= 3); // uop load + gemm + alu + finish
        assert!(rep.cycles >= rep.compute.busy_cycles);
    }
}
