//! Per-module activity recording — the data behind the paper's process
//! utilization visualizations (Figs 3 and 4). Each module logs busy
//! intervals tagged with what it was doing; the gantt renderer in
//! `analysis::gantt` turns these into the load/compute/store bars with
//! GEMM (red) vs ALU (green) distinction.

/// The three loosely-coupled processes (plus fetch, which the paper's
/// charts omit but which we record anyway).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Module {
    Fetch,
    Load,
    Compute,
    Store,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Activity {
    /// DMA transfer from DRAM into INP/WGT scratchpads.
    LoadDma,
    /// Padding fill overlapped with DMA (Fig 5).
    PadFill,
    /// GEMM execution (red in Fig 3).
    Gemm,
    /// ALU execution (green in Fig 3).
    Alu,
    /// Compute-side loads (UOP / ACC buffers).
    LoadUop,
    LoadAcc,
    /// Store DMA to DRAM.
    StoreDma,
    /// Instruction fetch DMA.
    FetchDma,
}

#[derive(Debug, Clone, Copy)]
pub struct Interval {
    pub module: Module,
    pub activity: Activity,
    pub start: u64,
    pub end: u64, // exclusive
}

#[derive(Debug, Default)]
pub struct ActivityTrace {
    pub enabled: bool,
    pub intervals: Vec<Interval>,
    /// Layer boundary markers (the red `vcr_finish` ticks of Fig 4).
    pub markers: Vec<(u64, String)>,
}

impl ActivityTrace {
    pub fn new(enabled: bool) -> ActivityTrace {
        ActivityTrace { enabled, ..Default::default() }
    }

    pub fn record(&mut self, module: Module, activity: Activity, start: u64, end: u64) {
        if self.enabled && end > start {
            self.intervals.push(Interval { module, activity, start, end });
        }
    }

    pub fn mark(&mut self, cycle: u64, label: &str) {
        if self.enabled {
            self.markers.push((cycle, label.to_string()));
        }
    }

    /// Total busy cycles for a module (intervals may not overlap within
    /// one module by construction).
    pub fn busy_cycles(&self, module: Module) -> u64 {
        self.intervals
            .iter()
            .filter(|iv| iv.module == module)
            .map(|iv| iv.end - iv.start)
            .sum()
    }

    pub fn busy_cycles_kind(&self, activity: Activity) -> u64 {
        self.intervals
            .iter()
            .filter(|iv| iv.activity == activity)
            .map(|iv| iv.end - iv.start)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_records_nothing() {
        let mut t = ActivityTrace::new(false);
        t.record(Module::Load, Activity::LoadDma, 0, 10);
        t.mark(5, "layer");
        assert!(t.intervals.is_empty());
        assert!(t.markers.is_empty());
    }

    #[test]
    fn busy_accounting() {
        let mut t = ActivityTrace::new(true);
        t.record(Module::Compute, Activity::Gemm, 0, 10);
        t.record(Module::Compute, Activity::Alu, 10, 14);
        t.record(Module::Load, Activity::LoadDma, 3, 9);
        assert_eq!(t.busy_cycles(Module::Compute), 14);
        assert_eq!(t.busy_cycles(Module::Load), 6);
        assert_eq!(t.busy_cycles_kind(Activity::Gemm), 10);
    }

    #[test]
    fn empty_intervals_dropped() {
        let mut t = ActivityTrace::new(true);
        t.record(Module::Load, Activity::LoadDma, 5, 5);
        assert!(t.intervals.is_empty());
    }
}
