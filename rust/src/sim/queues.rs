//! Command and dependency-token queues (§II-A).
//!
//! The four dependency queues (LD→CMP, CMP→LD, CMP→ST, ST→CMP) carry
//! single-bit tokens; `pop*` dependency flags block instruction start
//! until a token is present, `push*` flags deposit a token at instruction
//! completion. Bounded capacity matters: a full token queue back-pressures
//! the producer, and mis-set flags can deadlock the machine — which the
//! simulator detects and reports (§II-A: "Setting extraneous dependency
//! bits can result in longer cycle counts or even deadlock").

use crate::isa::Insn;
use std::collections::VecDeque;

/// A bounded single-bit token queue.
#[derive(Debug, Clone)]
pub struct TokenQueue {
    pub name: &'static str,
    count: usize,
    capacity: usize,
    pub pushes: u64,
    pub pops: u64,
}

impl TokenQueue {
    pub fn new(name: &'static str, capacity: usize) -> TokenQueue {
        TokenQueue { name, count: 0, capacity, pushes: 0, pops: 0 }
    }

    pub fn try_pop(&mut self) -> bool {
        if self.count > 0 {
            self.count -= 1;
            self.pops += 1;
            true
        } else {
            false
        }
    }

    pub fn try_push(&mut self) -> bool {
        if self.count < self.capacity {
            self.count += 1;
            self.pushes += 1;
            true
        } else {
            false
        }
    }

    pub fn tokens(&self) -> usize {
        self.count
    }

    /// Whether a push would currently succeed (used by the simulator's
    /// event scheduler to avoid busy-polling a blocked producer).
    pub fn has_space(&self) -> bool {
        self.count < self.capacity
    }
}

/// A bounded command queue between fetch and an execution module.
#[derive(Debug, Clone)]
pub struct CmdQueue {
    pub name: &'static str,
    items: VecDeque<Insn>,
    capacity: usize,
}

impl CmdQueue {
    pub fn new(name: &'static str, capacity: usize) -> CmdQueue {
        CmdQueue { name, items: VecDeque::new(), capacity }
    }

    pub fn has_space(&self) -> bool {
        self.items.len() < self.capacity
    }

    pub fn push(&mut self, insn: Insn) {
        assert!(self.has_space(), "cmd queue {} overflow", self.name);
        self.items.push_back(insn);
    }

    pub fn front(&self) -> Option<&Insn> {
        self.items.front()
    }

    pub fn pop(&mut self) -> Option<Insn> {
        self.items.pop_front()
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::DepFlags;

    #[test]
    fn token_queue_bounded() {
        let mut q = TokenQueue::new("t", 2);
        assert!(!q.try_pop());
        assert!(q.has_space());
        assert!(q.try_push());
        assert!(q.try_push());
        assert!(!q.has_space());
        assert!(!q.try_push(), "capacity reached");
        assert!(q.try_pop());
        assert!(q.has_space());
        assert_eq!(q.tokens(), 1);
        assert_eq!(q.pushes, 2);
        assert_eq!(q.pops, 1);
    }

    #[test]
    fn cmd_queue_fifo() {
        let mut q = CmdQueue::new("c", 2);
        q.push(Insn::Finish(DepFlags::NONE));
        q.push(Insn::Finish(DepFlags::NONE.pop_prev()));
        assert!(!q.has_space());
        let first = q.pop().unwrap();
        assert_eq!(first.deps(), DepFlags::NONE);
        assert_eq!(q.len(), 1);
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn cmd_queue_overflow_panics() {
        let mut q = CmdQueue::new("c", 1);
        q.push(Insn::Finish(DepFlags::NONE));
        q.push(Insn::Finish(DepFlags::NONE));
    }
}
