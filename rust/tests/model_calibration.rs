//! Calibration and property tests for the analytical cycle model
//! (`vta::model`) — the phase-1 scorer of the two-phase sweep.
//!
//! Two kinds of guarantees:
//!
//! * **calibration** — per-layer and whole-network estimates track
//!   timing-only tsim within [`model::CALIBRATION_SANITY_RATIO`] across
//!   the preset configurations × workload layers (the hard CI bound; the
//!   *measured* band, printed by these tests and recorded in
//!   EXPERIMENTS.md, is what the pruning epsilon is derived from — and
//!   the sweep acceptance test in `sweep_engine.rs` self-calibrates, so
//!   front correctness never rests on this bound);
//! * **monotonicity** — widening the memory interface or enabling
//!   execution-unit pipelining never increases an estimate, the
//!   properties the epsilon-band pruner's geometry relies on.

use vta::config::presets;
use vta::config::VtaConfig;
use vta::model::{self, calib};
use vta::workloads;

/// The calibration matrix: every preset geometry × a workload whose
/// channel blocks match it (micro nets exercise conv, depthwise, pool,
/// residual add, dense and the CPU-fallback path).
fn calibration_matrix() -> Vec<(VtaConfig, vta::compiler::graph::Graph)> {
    vec![
        (presets::tiny_config(), workloads::micro_resnet(4, 42)),
        (presets::tiny_config(), workloads::micro_mobilenet(4, 42)),
        (presets::default_config(), workloads::micro_resnet(16, 42)),
        (presets::scaled_config(1, 32, 32, 2, 32), workloads::micro_resnet(32, 42)),
    ]
}

#[test]
fn per_layer_estimates_within_documented_band() {
    let matrix = calibration_matrix();
    let all = calib::merge(matrix.iter().map(|(cfg, g)| calib::calibrate_graph(cfg, g)));
    assert!(!all.points.is_empty());
    // Print the measured band — EXPERIMENTS.md records it per PR, and
    // CI logs make it greppable.
    print!("{}", all.render_table());
    for p in &all.points {
        assert!(
            p.ratio() <= model::CALIBRATION_SANITY_RATIO,
            "{}: predicted {} vs measured {} (ratio {:.2}) exceeds the documented \
             sanity band {}",
            p.label,
            p.predicted,
            p.measured,
            p.ratio(),
            model::CALIBRATION_SANITY_RATIO
        );
    }
    // The whole-network ratio feeds the suggested pruning epsilon.
    assert!(all.suggested_epsilon().is_finite());
}

#[test]
fn network_estimate_monotone_in_memory_width() {
    let g = workloads::micro_resnet(4, 42);
    let mut prev = u64::MAX;
    for axi in [8usize, 16, 32, 64] {
        let mut cfg = presets::tiny_config();
        cfg.axi_bytes = axi;
        let pred = model::predict_graph(&cfg, &g).cycles;
        assert!(
            pred <= prev,
            "widening memory width must never increase the estimate: \
             axi {axi} predicts {pred} > {prev}"
        );
        prev = pred;
    }
}

#[test]
fn network_estimate_monotone_in_pipelining() {
    for (cfg, g) in calibration_matrix() {
        let mut unpiped = cfg.clone();
        unpiped.gemm_pipelined = false;
        unpiped.alu_pipelined = false;
        let fast = model::predict_graph(&cfg, &g).cycles;
        let slow = model::predict_graph(&unpiped, &g).cycles;
        assert!(
            fast <= slow,
            "{} on {}: enabling pipelining must never increase the estimate \
             ({fast} vs {slow})",
            g.name,
            cfg.name
        );
    }
}

#[test]
fn resnet18_prediction_is_fast_and_scales_sanely() {
    // The phase-1 scorer must price a full ResNet-18 grid point without
    // simulating: just assert it runs and orders MAC shapes correctly
    // (more MACs at the same bandwidth → fewer predicted cycles).
    let g = workloads::resnet(18, 56, 1);
    let small = model::predict_graph(&presets::scaled_config(1, 16, 16, 2, 32), &g).cycles;
    let large = model::predict_graph(&presets::scaled_config(1, 64, 64, 2, 32), &g).cycles;
    assert!(small > 0 && large > 0);
    assert!(
        large < small,
        "a 16x larger MAC array at equal bandwidth must predict fewer cycles \
         ({large} vs {small})"
    );
}

#[test]
fn calibration_report_suggests_sound_epsilon() {
    let (cfg, g) = &calibration_matrix()[0];
    let report = calib::calibrate_graph(cfg, g);
    let rho = report.max_ratio();
    // ε = ρ² − 1 must cover the measured band by construction.
    let eps = report.suggested_epsilon();
    assert!((1.0 + eps).sqrt() >= rho - 1e-9);
    assert!(report.geomean_ratio() <= rho + 1e-9);
}
