//! Sweep-engine integration tests: determinism (same grid + seed =>
//! identical results for any worker count), cache-resume correctness
//! (killing a sweep mid-run and resuming yields the same frontier
//! without re-simulating completed points), and Pareto-frontier
//! invariants as properties over random point clouds (`util/prop`).

use vta::config::presets;
use vta::repro::{mark_pareto, Fig13Row};
use vta::sweep::pareto::{dominates, ParetoFront, ParetoPoint};
use vta::sweep::{self, SweepOptions, SweepSpec, WorkloadSpec};
use vta::util::prop::Prop;
use vta::{prop_assert, prop_assert_eq};

use std::path::PathBuf;

/// A fast 8-point grid: the micro-ResNet on tiny-geometry variants
/// (2 AXI widths x 2 scratchpad scalings x 2 input seeds).
fn micro_spec() -> SweepSpec {
    let mut configs = Vec::new();
    for axi in [8usize, 16] {
        for scale in [1usize, 2] {
            let mut cfg = presets::tiny_config();
            cfg.name = format!("tiny-s{scale}-m{axi}");
            cfg.axi_bytes = axi;
            cfg.inp_depth *= scale;
            cfg.wgt_depth *= scale;
            cfg.acc_depth *= scale;
            configs.push(cfg);
        }
    }
    SweepSpec {
        configs,
        workloads: vec![WorkloadSpec::Micro { block: 4 }],
        seeds: vec![7, 8],
        graph_seed: 42,
    }
}

fn temp_cache(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("vta_sweep_test_{}_{name}.jsonl", std::process::id()))
}

fn run_opts(jobs: usize, cache: Option<PathBuf>, resume: bool) -> SweepOptions {
    SweepOptions { jobs, cache_path: cache, resume, ..Default::default() }
}

/// ISSUE-2 acceptance invariant on a reduced grid: the memoized
/// timing-only fast path must produce bit-identical results (cycles,
/// counters, area — the whole `PointResult`) to full functional
/// simulation with the memo disabled.
#[test]
fn memo_timing_only_results_bit_identical() {
    let spec = micro_spec();
    let baseline = sweep::run(&spec, &run_opts(2, None, false)).unwrap();
    let fast = sweep::run(
        &spec,
        &SweepOptions { jobs: 2, memo: true, timing_only: true, ..Default::default() },
    )
    .unwrap();
    assert_eq!(baseline.results, fast.results, "fast path must be bit-identical");
    assert_eq!(baseline.front.points(), fast.front.points(), "frontier must be identical");
    assert!(
        fast.memo_hits > 0,
        "the grid repeats layer shapes (2 seeds per config); expected memo reuse"
    );
    // The memo alone (functional mode, hits replayed through the exec
    // core) must also change nothing.
    let memo_functional = sweep::run(
        &spec,
        &SweepOptions { jobs: 2, memo: true, ..Default::default() },
    )
    .unwrap();
    assert_eq!(baseline.results, memo_functional.results);
}

/// The layer-memo spill warms a restarted sweep: lose the result cache
/// but keep the spill, and every point re-simulates with zero layer
/// simulations.
#[test]
fn memo_spill_warm_restart_simulates_no_layers() {
    let spec = micro_spec();
    let cache = temp_cache("memo_spill");
    let spill = cache.with_file_name(format!(
        "{}.layers.jsonl",
        cache.file_stem().unwrap().to_string_lossy()
    ));
    let opts = SweepOptions {
        jobs: 2,
        cache_path: Some(cache.clone()),
        resume: false,
        progress: false,
        memo: true,
        timing_only: true,
    };
    let first = sweep::run(&spec, &opts).unwrap();
    assert!(spill.exists(), "memo must spill next to the result cache");
    std::fs::remove_file(&cache).unwrap();
    let second = sweep::run(&spec, &SweepOptions { resume: true, ..opts.clone() }).unwrap();
    assert_eq!(first.results, second.results);
    assert_eq!(second.simulated, spec.jobs().len(), "result cache was lost");
    assert_eq!(second.memo_misses, 0, "every layer must come from the spill");
    std::fs::remove_file(&cache).ok();
    std::fs::remove_file(&spill).ok();
}

#[test]
fn parallel_results_identical_to_serial() {
    let spec = micro_spec();
    let n = spec.jobs().len();
    assert_eq!(n, 8);
    let serial = sweep::run(&spec, &run_opts(1, None, false)).unwrap();
    let parallel = sweep::run(&spec, &run_opts(4, None, false)).unwrap();
    assert_eq!(serial.simulated, n);
    assert_eq!(parallel.simulated, n);
    assert_eq!(
        serial.results, parallel.results,
        "results must be identical for any worker count"
    );
    assert_eq!(serial.front.points(), parallel.front.points(), "frontier must be identical");
    assert!(!serial.front.is_empty());
}

#[test]
fn results_land_in_grid_order_with_full_metrics() {
    let spec = micro_spec();
    let outcome = sweep::run(&spec, &run_opts(3, None, false)).unwrap();
    // Row order: config-major, then seed — exactly spec.jobs() order.
    let jobs = spec.jobs();
    for (job, r) in jobs.iter().zip(&outcome.results) {
        assert_eq!(r.config, job.cfg);
        assert_eq!(r.workload, "micro@4");
        assert_eq!(r.seed, job.seed);
        assert!(r.cycles > 0, "tsim must report cycles");
        assert!(r.macs > 0 && r.insns > 0 && r.dram_rd > 0 && r.dram_wr > 0);
        assert!(r.scaled_area > 0.0);
    }
}

#[test]
fn cache_resume_completes_without_resimulating() {
    let spec = micro_spec();
    let path = temp_cache("resume");
    let full = sweep::run(&spec, &run_opts(2, Some(path.clone()), false)).unwrap();
    assert_eq!(full.simulated, full.results.len());
    assert_eq!(full.cached, 0);

    // Simulate a kill mid-sweep: keep only the first half of the cache
    // records on disk.
    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), full.results.len(), "one JSONL record per point");
    let keep = lines.len() / 2;
    std::fs::write(&path, lines[..keep].join("\n") + "\n").unwrap();

    let resumed = sweep::run(&spec, &run_opts(3, Some(path.clone()), true)).unwrap();
    assert_eq!(resumed.cached, keep, "surviving records must be served from cache");
    assert_eq!(resumed.simulated, full.results.len() - keep, "only lost points re-simulate");
    assert_eq!(resumed.results, full.results, "resume must reproduce the cold run exactly");
    assert_eq!(resumed.front.points(), full.front.points(), "same frontier after resume");

    // A second resume finds every point cached: no simulation at all.
    let warm = sweep::run(&spec, &run_opts(4, Some(path.clone()), true)).unwrap();
    assert_eq!(warm.simulated, 0, "warm-cache re-run must not simulate");
    assert_eq!(warm.cached, full.results.len());
    assert_eq!(warm.results, full.results);
    assert_eq!(warm.front.points(), full.front.points());
    std::fs::remove_file(&path).ok();
}

#[test]
fn corrupt_cache_tail_is_resimulated_not_fatal() {
    let spec = micro_spec();
    let path = temp_cache("corrupt");
    let full = sweep::run(&spec, &run_opts(2, Some(path.clone()), false)).unwrap();
    // Append a truncated record (kill mid-write): resume must ignore it
    // and still serve every complete record from cache.
    let text = std::fs::read_to_string(&path).unwrap();
    let tail = &text[..text.len() / 3];
    std::fs::write(&path, format!("{text}{}", tail.replace('\n', " "))).unwrap();
    let warm = sweep::run(&spec, &run_opts(2, Some(path.clone()), true)).unwrap();
    assert_eq!(warm.simulated, 0, "all complete records were present");
    assert_eq!(warm.results, full.results);
    std::fs::remove_file(&path).ok();
}

#[test]
fn without_resume_cache_is_cold() {
    let spec = micro_spec();
    let path = temp_cache("cold");
    sweep::run(&spec, &run_opts(2, Some(path.clone()), false)).unwrap();
    // resume: false truncates and re-simulates everything.
    let again = sweep::run(&spec, &run_opts(2, Some(path.clone()), false)).unwrap();
    assert_eq!(again.cached, 0);
    assert_eq!(again.simulated, again.results.len());
    std::fs::remove_file(&path).ok();
}

// ---------------------------------------------------------------- pareto

#[test]
fn prop_incremental_front_equals_batch_marking() {
    // Small coordinate ranges force heavy tie/duplicate coverage.
    Prop::new("pareto-incremental").cases(300).run(|g| {
        let n = g.usize(0, 40);
        let pts: Vec<ParetoPoint> = (0..n)
            .map(|i| ParetoPoint {
                area: g.i64(0, 15) as f64,
                cycles: g.i64(0, 15) as u64,
                id: i,
            })
            .collect();
        let mut front = ParetoFront::new();
        for p in &pts {
            front.insert(p.area, p.cycles, p.id);
        }
        let naive: Vec<usize> = pts
            .iter()
            .filter(|p| !pts.iter().any(|q| dominates(q, p)))
            .map(|p| p.id)
            .collect();
        prop_assert_eq!(front.ids(), naive);
        Ok(())
    });
}

#[test]
fn prop_front_invariant_under_insertion_order() {
    Prop::new("pareto-order-invariance").cases(200).run(|g| {
        let n = g.usize(0, 24);
        let pts: Vec<ParetoPoint> = (0..n)
            .map(|i| ParetoPoint {
                area: g.i64(0, 10) as f64,
                cycles: g.i64(0, 10) as u64,
                id: i,
            })
            .collect();
        let mut perm: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = g.usize(0, i);
            perm.swap(i, j);
        }
        let mut forward = ParetoFront::new();
        for p in &pts {
            forward.insert(p.area, p.cycles, p.id);
        }
        let mut shuffled = ParetoFront::new();
        for &k in &perm {
            shuffled.insert(pts[k].area, pts[k].cycles, pts[k].id);
        }
        prop_assert_eq!(forward.ids(), shuffled.ids());
        prop_assert_eq!(forward.points(), shuffled.points());
        Ok(())
    });
}

#[test]
fn prop_front_matches_repro_mark_pareto() {
    // The engine's incremental frontier and the legacy batch
    // `mark_pareto` must agree point-for-point, ties included.
    Prop::new("front-vs-mark-pareto").cases(200).run(|g| {
        let n = g.usize(0, 30);
        let mut rows: Vec<Fig13Row> = (0..n)
            .map(|i| Fig13Row {
                config: format!("p{i}"),
                block: 16,
                cycles: g.i64(0, 12) as u64,
                scaled_area: g.i64(0, 12) as f64,
                pareto: false,
            })
            .collect();
        let mut front = ParetoFront::new();
        for (i, r) in rows.iter().enumerate() {
            front.insert(r.scaled_area, r.cycles, i);
        }
        mark_pareto(&mut rows);
        let expect: Vec<usize> = rows
            .iter()
            .enumerate()
            .filter(|(_, r)| r.pareto)
            .map(|(i, _)| i)
            .collect();
        prop_assert_eq!(front.ids(), expect);
        Ok(())
    });
}

#[test]
fn prop_frontier_points_mutually_non_dominating() {
    Prop::new("frontier-antichain").cases(150).run(|g| {
        let n = g.usize(0, 30);
        let mut front = ParetoFront::new();
        for i in 0..n {
            front.insert(g.i64(0, 12) as f64, g.i64(0, 12) as u64, i);
        }
        let pts = front.points();
        for a in &pts {
            for b in &pts {
                prop_assert!(
                    !dominates(a, b),
                    "frontier must be an antichain: {a:?} dominates {b:?}"
                );
            }
        }
        Ok(())
    });
}
