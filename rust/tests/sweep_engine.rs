//! Sweep-engine integration tests: determinism (same grid + seed =>
//! identical results for any worker count), cache-resume correctness
//! (killing a sweep mid-run and resuming yields the same frontier
//! without re-simulating completed points), and Pareto-frontier
//! invariants as properties over random point clouds (`util/prop`).

use vta::compiler::residency::ResidencyMode;
use vta::config::presets;
use vta::engine::{BackendKind, VtaError};
use vta::model;
use vta::repro::{mark_pareto, Fig13Row};
use vta::sweep::pareto::{dominates, epsilon_band_survivors, ParetoFront, ParetoPoint};
use vta::sweep::{self, SweepJob, SweepOptions, SweepSpec, TwoPhaseOptions, WorkloadSpec};
use vta::util::prop::Prop;
use vta::{prop_assert, prop_assert_eq};

use std::path::PathBuf;

/// A fast 8-point grid: the micro-ResNet on tiny-geometry variants
/// (2 AXI widths x 2 scratchpad scalings x 2 input seeds).
fn micro_spec() -> SweepSpec {
    let mut configs = Vec::new();
    for axi in [8usize, 16] {
        for scale in [1usize, 2] {
            let mut cfg = presets::tiny_config();
            cfg.name = format!("tiny-s{scale}-m{axi}");
            cfg.axi_bytes = axi;
            cfg.inp_depth *= scale;
            cfg.wgt_depth *= scale;
            cfg.acc_depth *= scale;
            configs.push(cfg);
        }
    }
    SweepSpec {
        configs,
        workloads: vec![WorkloadSpec::Micro { block: 4 }],
        seeds: vec![7, 8],
        graph_seed: 42,
    }
}

fn temp_cache(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("vta_sweep_test_{}_{name}.jsonl", std::process::id()))
}

fn run_opts(jobs: usize, cache: Option<PathBuf>, resume: bool) -> SweepOptions {
    SweepOptions { jobs, cache_path: cache, resume, ..Default::default() }
}

/// ISSUE-2 acceptance invariant on a reduced grid: the memoized
/// timing-only fast path must produce bit-identical results (cycles,
/// counters, area — the whole `PointResult`) to full functional
/// simulation with the memo disabled.
#[test]
fn memo_timing_only_results_bit_identical() {
    let spec = micro_spec();
    let baseline = sweep::run(&spec, &run_opts(2, None, false)).unwrap();
    let fast = sweep::run(
        &spec,
        &SweepOptions {
            jobs: 2,
            memo: true,
            backend: BackendKind::TsimTiming,
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(baseline.results, fast.results, "fast path must be bit-identical");
    assert_eq!(baseline.front.points(), fast.front.points(), "frontier must be identical");
    assert!(
        fast.memo_hits > 0,
        "the grid repeats layer shapes (2 seeds per config); expected memo reuse"
    );
    // The memo alone (functional mode, hits replayed through the exec
    // core) must also change nothing.
    let memo_functional = sweep::run(
        &spec,
        &SweepOptions { jobs: 2, memo: true, ..Default::default() },
    )
    .unwrap();
    assert_eq!(baseline.results, memo_functional.results);
}

/// The layer-memo spill warms a restarted sweep: lose the result cache
/// but keep the spill, and every point re-simulates with zero layer
/// simulations.
#[test]
fn memo_spill_warm_restart_simulates_no_layers() {
    let spec = micro_spec();
    let cache = temp_cache("memo_spill");
    let spill = cache.with_file_name(format!(
        "{}.layers.jsonl",
        cache.file_stem().unwrap().to_string_lossy()
    ));
    let opts = SweepOptions {
        jobs: 2,
        cache_path: Some(cache.clone()),
        memo: true,
        backend: BackendKind::TsimTiming,
        ..Default::default()
    };
    let first = sweep::run(&spec, &opts).unwrap();
    assert!(spill.exists(), "memo must spill next to the result cache");
    std::fs::remove_file(&cache).unwrap();
    let second = sweep::run(&spec, &SweepOptions { resume: true, ..opts.clone() }).unwrap();
    assert_eq!(first.results, second.results);
    assert_eq!(second.simulated, spec.jobs().len(), "result cache was lost");
    assert_eq!(second.memo_misses, 0, "every layer must come from the spill");
    std::fs::remove_file(&cache).ok();
    std::fs::remove_file(&spill).ok();
}

#[test]
fn parallel_results_identical_to_serial() {
    let spec = micro_spec();
    let n = spec.jobs().len();
    assert_eq!(n, 8);
    let serial = sweep::run(&spec, &run_opts(1, None, false)).unwrap();
    let parallel = sweep::run(&spec, &run_opts(4, None, false)).unwrap();
    assert_eq!(serial.simulated, n);
    assert_eq!(parallel.simulated, n);
    assert_eq!(
        serial.results, parallel.results,
        "results must be identical for any worker count"
    );
    assert_eq!(serial.front.points(), parallel.front.points(), "frontier must be identical");
    assert!(!serial.front.is_empty());
}

#[test]
fn results_land_in_grid_order_with_full_metrics() {
    let spec = micro_spec();
    let outcome = sweep::run(&spec, &run_opts(3, None, false)).unwrap();
    // Row order: config-major, then seed — exactly spec.jobs() order.
    let jobs = spec.jobs();
    for (job, r) in jobs.iter().zip(&outcome.results) {
        assert_eq!(r.config, job.cfg);
        assert_eq!(r.workload, "micro@4");
        assert_eq!(r.seed, job.seed);
        assert!(r.cycles > 0, "tsim must report cycles");
        assert!(r.macs > 0 && r.insns > 0 && r.dram_rd > 0 && r.dram_wr > 0);
        assert!(r.scaled_area > 0.0);
    }
}

#[test]
fn cache_resume_completes_without_resimulating() {
    let spec = micro_spec();
    let path = temp_cache("resume");
    let full = sweep::run(&spec, &run_opts(2, Some(path.clone()), false)).unwrap();
    assert_eq!(full.simulated, full.results.len());
    assert_eq!(full.cached, 0);

    // Simulate a kill mid-sweep: keep only the first half of the cache
    // records on disk.
    let text = std::fs::read_to_string(&path).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), full.results.len(), "one JSONL record per point");
    let keep = lines.len() / 2;
    std::fs::write(&path, lines[..keep].join("\n") + "\n").unwrap();

    let resumed = sweep::run(&spec, &run_opts(3, Some(path.clone()), true)).unwrap();
    assert_eq!(resumed.cached, keep, "surviving records must be served from cache");
    assert_eq!(resumed.simulated, full.results.len() - keep, "only lost points re-simulate");
    assert_eq!(resumed.results, full.results, "resume must reproduce the cold run exactly");
    assert_eq!(resumed.front.points(), full.front.points(), "same frontier after resume");

    // A second resume finds every point cached: no simulation at all.
    let warm = sweep::run(&spec, &run_opts(4, Some(path.clone()), true)).unwrap();
    assert_eq!(warm.simulated, 0, "warm-cache re-run must not simulate");
    assert_eq!(warm.cached, full.results.len());
    assert_eq!(warm.results, full.results);
    assert_eq!(warm.front.points(), full.front.points());
    std::fs::remove_file(&path).ok();
}

#[test]
fn corrupt_cache_tail_is_resimulated_not_fatal() {
    let spec = micro_spec();
    let path = temp_cache("corrupt");
    let full = sweep::run(&spec, &run_opts(2, Some(path.clone()), false)).unwrap();
    // Append a truncated record (kill mid-write): resume must ignore it
    // and still serve every complete record from cache.
    let text = std::fs::read_to_string(&path).unwrap();
    let tail = &text[..text.len() / 3];
    std::fs::write(&path, format!("{text}{}", tail.replace('\n', " "))).unwrap();
    let warm = sweep::run(&spec, &run_opts(2, Some(path.clone()), true)).unwrap();
    assert_eq!(warm.simulated, 0, "all complete records were present");
    assert_eq!(warm.results, full.results);
    std::fs::remove_file(&path).ok();
}

#[test]
fn without_resume_cache_is_cold() {
    let spec = micro_spec();
    let path = temp_cache("cold");
    sweep::run(&spec, &run_opts(2, Some(path.clone()), false)).unwrap();
    // resume: false truncates and re-simulates everything.
    let again = sweep::run(&spec, &run_opts(2, Some(path.clone()), false)).unwrap();
    assert_eq!(again.cached, 0);
    assert_eq!(again.simulated, again.results.len());
    std::fs::remove_file(&path).ok();
}

// ------------------------------------------------------------- two-phase

/// Satellite (hash consolidation): the exact cache key of a known
/// (config, workload, seed, graph_seed) point, pinned as a golden
/// value. `sweep::stable_hash64` is the canonical `util::hash::fnv1a64`
/// — if this value ever moves, every on-disk sweep cache silently goes
/// cold; change the key format only with a deliberate
/// `SWEEP_SCHEMA_VERSION` bump and update this constant (computed
/// independently with a reference FNV-1a implementation).
#[test]
fn cache_key_golden_value() {
    let job = SweepJob {
        index: 0,
        cfg: presets::tiny_config(),
        workload: WorkloadSpec::Micro { block: 4 },
        seed: 7,
        graph_seed: 42,
    };
    assert_eq!(
        job.cache_key(ResidencyMode::Lru),
        0x74e9ea84debbc039,
        "v5 cache key of (tiny, micro@4, seed 7, graph_seed 42, lru): the v5|s4 prefix and \
         the config JSON's new \"precision\" field both feed the hash"
    );
    // And the hash itself matches the published FNV-1a vectors through
    // the sweep-facing name.
    assert_eq!(sweep::stable_hash64(""), 0xcbf29ce484222325);
    assert_eq!(sweep::stable_hash64("foobar"), 0x85944171f73967e8);
}

fn two_phase_opts(jobs: usize, epsilon: f64) -> SweepOptions {
    SweepOptions {
        jobs,
        memo: true,
        backend: BackendKind::TsimTiming,
        two_phase: Some(TwoPhaseOptions { epsilon }),
        ..Default::default()
    }
}

/// ISSUE-3 acceptance invariant on the reduced grid: with a pruning
/// band covering the model's error — self-calibrated from this very
/// grid, so the test can only fail on engine logic, never on model
/// quality — the two-phase front is point-for-point identical to the
/// full-tsim front, every survivor is bit-identical to the full run's
/// measured result, and the whole thing is deterministic across worker
/// counts.
#[test]
fn two_phase_front_identical_to_full_tsim_front() {
    let spec = micro_spec();
    let jobs = spec.jobs();
    let full = sweep::run(&spec, &run_opts(2, None, false)).unwrap();

    // Self-calibrate: worst multiplicative model error ρ on this grid,
    // then the provably sound band ε = ρ² − 1 (DESIGN.md), with margin.
    let mut rho: f64 = 1.0;
    for (j, job) in jobs.iter().enumerate() {
        let graph = job.workload.build(job.graph_seed);
        let pred = model::predict_graph(&job.cfg, &graph).cycles.max(1) as f64;
        let meas = full.results[j].cycles.max(1) as f64;
        rho = rho.max((pred / meas).max(meas / pred));
    }
    let epsilon = model::epsilon_for_ratio(rho * 1.001);

    let two = sweep::run(&spec, &two_phase_opts(2, epsilon)).unwrap();

    // Survivors + pruned partition the grid; job_indices maps dense
    // result positions back to grid job order.
    assert_eq!(two.results.len() + two.pruned.len(), jobs.len());
    assert_eq!(two.results.len(), two.job_indices.len());

    // Every survivor is bit-identical (modulo the predicted-cycles
    // annotation) to the full run's measured result for the same job:
    // the reported front can only ever contain tsim-measured numbers.
    for (d, r) in two.results.iter().enumerate() {
        let j = two.job_indices[d];
        assert!(r.predicted_cycles.is_some(), "two-phase must annotate predictions");
        let mut stripped = r.clone();
        stripped.predicted_cycles = None;
        assert_eq!(stripped, full.results[j], "survivor {j} must be measured, not estimated");
    }

    // Front equality, mapped to grid job indices (full-run ids are
    // already grid indices).
    let map_front = |front: &ParetoFront, idx: &[usize]| -> Vec<(u64, u64, usize)> {
        let mut v: Vec<(u64, u64, usize)> = front
            .points()
            .iter()
            .map(|p| (p.area.to_bits(), p.cycles, idx[p.id]))
            .collect();
        v.sort_unstable();
        v
    };
    let identity: Vec<usize> = (0..jobs.len()).collect();
    assert_eq!(
        map_front(&two.front, &two.job_indices),
        map_front(&full.front, &identity),
        "two-phase front must equal the full-tsim front point-for-point"
    );

    // Pruning is a pure function of (grid, model, ε): a re-run at a
    // different worker count reproduces results, prune set and front.
    let again = sweep::run(&spec, &two_phase_opts(4, epsilon)).unwrap();
    assert_eq!(two.results, again.results);
    assert_eq!(two.pruned, again.pruned);
    assert_eq!(two.front.points(), again.front.points());
    assert_eq!(two.job_indices, again.job_indices);
}

/// Pruning actually prunes, and can only *drop* points, never fabricate
/// front entries: a config that is strictly worse on both axes by
/// construction (larger uop scratchpad → strictly larger area; much
/// larger DRAM latency → strictly larger prediction, via the additive
/// latency terms of every layer estimate) is eliminated at ε = 0, and
/// every reported front point carries the same measured cycles as the
/// full-tsim run.
#[test]
fn two_phase_prunes_dominated_corner_and_never_fabricates() {
    let mut spec = micro_spec();
    let mut bad = presets::tiny_config();
    bad.name = "tiny-bad".into();
    bad.uop_depth *= 2; // strictly more area
    bad.dram_latency *= 1000; // strictly (and overwhelmingly) larger predicted cycles
    spec.configs.push(bad);
    let n_jobs = spec.jobs().len();

    let full = sweep::run(&spec, &run_opts(2, None, false)).unwrap();
    let two = sweep::run(&spec, &two_phase_opts(2, 0.0)).unwrap();

    assert!(
        two.pruned.len() >= 2,
        "both seeds of the dominated corner must be pruned, got {:?}",
        two.pruned
    );
    assert_eq!(two.results.len() + two.pruned.len(), n_jobs);
    // Pruned points carry predictions only — and they were never
    // simulated (simulated + cached covers exactly the survivors).
    assert_eq!(two.simulated + two.cached, two.results.len());
    // Every front point the two-phase run reports exists in the full
    // run with identical measured cycles (drop-only, never fabricate).
    for p in two.front.points() {
        let j = two.job_indices[p.id];
        assert_eq!(
            two.results[p.id].cycles, full.results[j].cycles,
            "front point {j} must carry the full run's measured cycles"
        );
    }
}

/// Satellite regression (infeasible grid points): a config whose
/// scratchpads cannot hold even the minimal fallback tiling used to be
/// silently dropped by the sweep (the worker's tiling search panicked /
/// errored the whole run). It must now surface as a typed
/// [`sweep::InfeasiblePoint`] with a reason, while every feasible point
/// still evaluates and the frontier is built from feasible points only.
#[test]
fn infeasible_config_reported_not_silently_dropped() {
    let mut spec = micro_spec();
    let mut cramped = presets::tiny_config();
    cramped.name = "tiny-cramped".into();
    // One scratchpad row each: no tiling of the micro network fits.
    cramped.inp_depth = 1;
    cramped.wgt_depth = 1;
    cramped.acc_depth = 1;
    spec.configs.push(cramped);
    let n_feasible = micro_spec().jobs().len();
    let n_jobs = spec.jobs().len();
    assert_eq!(n_jobs, n_feasible + 2, "the cramped config contributes one job per seed");

    let outcome = sweep::run(&spec, &run_opts(2, None, false))
        .expect("an infeasible grid point must not fail the sweep");
    assert_eq!(outcome.infeasible.len(), 2, "both seeds of the cramped config are screened");
    for p in &outcome.infeasible {
        assert_eq!(spec.jobs()[p.index].cfg.name, "tiny-cramped");
        assert!(!p.reason.is_empty(), "screening must say why the point is infeasible");
    }
    assert_eq!(outcome.results.len(), n_feasible, "every feasible point still evaluates");
    assert!(outcome
        .job_indices
        .iter()
        .all(|&j| outcome.infeasible.iter().all(|p| p.index != j)));
    // The same grid under --residency off screens identically: feasibility
    // is a property of (config, workload), not of the residency heuristic.
    let off = sweep::run(
        &spec,
        &SweepOptions { residency: ResidencyMode::Off, ..run_opts(2, None, false) },
    )
    .unwrap();
    assert_eq!(off.infeasible.len(), outcome.infeasible.len());
}

/// Tentpole acceptance (sweep leg): the default-residency (LRU) sweep
/// and a residency-off sweep agree on every functional counter — only
/// cycles and DMA traffic may differ, and LRU can never be slower.
#[test]
fn residency_modes_agree_on_functional_counters() {
    let spec = micro_spec();
    let lru = sweep::run(&spec, &run_opts(2, None, false)).unwrap();
    let off = sweep::run(
        &spec,
        &SweepOptions { residency: ResidencyMode::Off, ..run_opts(2, None, false) },
    )
    .unwrap();
    assert_eq!(lru.results.len(), off.results.len());
    let mut some_faster = false;
    for (l, o) in lru.results.iter().zip(&off.results) {
        assert_eq!(l.macs, o.macs, "residency must never change what executes");
        assert_eq!(l.insns, o.insns);
        assert!(l.cycles <= o.cycles, "eliding DMA can never add cycles");
        assert!(l.dram_rd <= o.dram_rd);
        some_faster |= l.cycles < o.cycles;
    }
    assert!(some_faster, "the micro grid has cross-layer reuse to elide");
}

#[test]
fn prop_epsilon_band_contains_front_and_is_monotone() {
    Prop::new("epsilon-band").cases(200).run(|g| {
        let n = g.usize(0, 40);
        let pts: Vec<(f64, u64)> = (0..n)
            .map(|_| (g.i64(0, 12) as f64, g.i64(0, 12) as u64))
            .collect();
        let eps = g.i64(0, 30) as f64 / 10.0;
        let survive = epsilon_band_survivors(&pts, eps);
        let mut front = ParetoFront::new();
        for (i, &(a, c)) in pts.iter().enumerate() {
            front.insert(a, c, i);
        }
        for id in front.ids() {
            prop_assert!(survive[id], "front point {id} must survive any epsilon >= 0");
        }
        let tighter = epsilon_band_survivors(&pts, eps / 2.0);
        for i in 0..n {
            prop_assert!(!tighter[i] || survive[i], "survivors must be monotone in epsilon");
        }
        Ok(())
    });
}

// ---------------------------------------------------------------- pareto

#[test]
fn prop_incremental_front_equals_batch_marking() {
    // Small coordinate ranges force heavy tie/duplicate coverage.
    Prop::new("pareto-incremental").cases(300).run(|g| {
        let n = g.usize(0, 40);
        let pts: Vec<ParetoPoint> = (0..n)
            .map(|i| ParetoPoint {
                area: g.i64(0, 15) as f64,
                cycles: g.i64(0, 15) as u64,
                id: i,
            })
            .collect();
        let mut front = ParetoFront::new();
        for p in &pts {
            front.insert(p.area, p.cycles, p.id);
        }
        let naive: Vec<usize> = pts
            .iter()
            .filter(|p| !pts.iter().any(|q| dominates(q, p)))
            .map(|p| p.id)
            .collect();
        prop_assert_eq!(front.ids(), naive);
        Ok(())
    });
}

#[test]
fn prop_front_invariant_under_insertion_order() {
    Prop::new("pareto-order-invariance").cases(200).run(|g| {
        let n = g.usize(0, 24);
        let pts: Vec<ParetoPoint> = (0..n)
            .map(|i| ParetoPoint {
                area: g.i64(0, 10) as f64,
                cycles: g.i64(0, 10) as u64,
                id: i,
            })
            .collect();
        let mut perm: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = g.usize(0, i);
            perm.swap(i, j);
        }
        let mut forward = ParetoFront::new();
        for p in &pts {
            forward.insert(p.area, p.cycles, p.id);
        }
        let mut shuffled = ParetoFront::new();
        for &k in &perm {
            shuffled.insert(pts[k].area, pts[k].cycles, pts[k].id);
        }
        prop_assert_eq!(forward.ids(), shuffled.ids());
        prop_assert_eq!(forward.points(), shuffled.points());
        Ok(())
    });
}

#[test]
fn prop_front_matches_repro_mark_pareto() {
    // The engine's incremental frontier and the legacy batch
    // `mark_pareto` must agree point-for-point, ties included.
    Prop::new("front-vs-mark-pareto").cases(200).run(|g| {
        let n = g.usize(0, 30);
        let mut rows: Vec<Fig13Row> = (0..n)
            .map(|i| Fig13Row {
                config: format!("p{i}"),
                block: 16,
                cycles: g.i64(0, 12) as u64,
                scaled_area: g.i64(0, 12) as f64,
                pareto: false,
            })
            .collect();
        let mut front = ParetoFront::new();
        for (i, r) in rows.iter().enumerate() {
            front.insert(r.scaled_area, r.cycles, i);
        }
        mark_pareto(&mut rows);
        let expect: Vec<usize> = rows
            .iter()
            .enumerate()
            .filter(|(_, r)| r.pareto)
            .map(|(i, _)| i)
            .collect();
        prop_assert_eq!(front.ids(), expect);
        Ok(())
    });
}

// ------------------------------------------------------- engine backends

/// Satellite regression: `jobs: 0` (auto) must clamp once — at options
/// construction and to the pending-point count — so a single-CPU
/// container never spawns a worker per job. `SweepOutcome::workers`
/// records what actually ran.
#[test]
fn worker_count_clamped_to_parallelism_and_pending() {
    assert_eq!(
        SweepOptions::default().jobs,
        sweep::effective_jobs(0),
        "default options resolve jobs at construction, not at spawn time"
    );
    let spec = micro_spec();
    let outcome = sweep::run(&spec, &run_opts(0, None, false)).unwrap();
    assert!(outcome.workers >= 1);
    assert!(outcome.workers <= sweep::effective_jobs(0), "never more workers than cores");
    assert!(outcome.workers <= outcome.simulated, "never more workers than pending points");
    // A fully cached run spawns no workers at all.
    let path = temp_cache("worker_clamp");
    sweep::run(&spec, &run_opts(0, Some(path.clone()), false)).unwrap();
    let warm = sweep::run(&spec, &run_opts(0, Some(path.clone()), true)).unwrap();
    assert_eq!(warm.workers, 0, "warm-cache runs have nothing to shard");
    std::fs::remove_file(&path).ok();
}

/// Capability mismatches are typed errors, not panics: fsim produces no
/// cycles, so a sweep over it is rejected up front.
#[test]
fn fsim_backend_rejected_with_typed_error() {
    let spec = micro_spec();
    let err = sweep::run(
        &spec,
        &SweepOptions { backend: BackendKind::Fsim, ..Default::default() },
    )
    .expect_err("fsim sweep must be rejected");
    assert!(matches!(err, VtaError::Unsupported(_)), "got {err:?}");
}

/// An analytical-backend sweep scores the whole grid through the same
/// engine path: every result is flagged unmeasured, carries the model's
/// prediction as its cycle count, and never lands in the on-disk cache.
#[test]
fn analytical_backend_sweeps_grid_without_simulating() {
    let spec = micro_spec();
    let path = temp_cache("analytical");
    let outcome = sweep::run(
        &spec,
        &SweepOptions {
            backend: BackendKind::Analytical,
            cache_path: Some(path.clone()),
            ..Default::default()
        },
    )
    .unwrap();
    let jobs = spec.jobs();
    assert_eq!(outcome.results.len(), jobs.len());
    for (job, r) in jobs.iter().zip(&outcome.results) {
        assert!(!r.measured, "analytical results must be flagged unmeasured");
        let graph = job.workload.build(job.graph_seed);
        let pred = model::predict_graph(&job.cfg, &graph).cycles;
        assert_eq!(r.cycles, pred, "cycles must equal the model prediction");
        assert_eq!(r.predicted_cycles, Some(pred));
        assert_eq!(r.macs, 0, "nothing executed, so counters stay zero");
    }
    assert!(!path.exists(), "predictions must never touch the measured-results cache");
}

#[test]
fn prop_frontier_points_mutually_non_dominating() {
    Prop::new("frontier-antichain").cases(150).run(|g| {
        let n = g.usize(0, 30);
        let mut front = ParetoFront::new();
        for i in 0..n {
            front.insert(g.i64(0, 12) as f64, g.i64(0, 12) as u64, i);
        }
        let pts = front.points();
        for a in &pts {
            for b in &pts {
                prop_assert!(
                    !dominates(a, b),
                    "frontier must be an antichain: {a:?} dominates {b:?}"
                );
            }
        }
        Ok(())
    });
}
