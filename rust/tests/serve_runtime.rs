//! Serving-runtime acceptance tests: the determinism contract (worker
//! count never changes the schedule or the report), the batching and
//! amortization invariants, and the typed rejection surface — the
//! `backend_parity.rs` style applied to the serving layer.

use vta::config::presets;
use vta::engine::{BackendKind, VtaError};
use vta::serve::{
    self, schedule_digest, ArrivalSpec, Request, ServeOptions, SessionPool,
};
use vta::sweep::WorkloadSpec;

fn micro_opts() -> ServeOptions {
    ServeOptions {
        cfg: presets::tiny_config(),
        backend: BackendKind::TsimTiming,
        workloads: vec![WorkloadSpec::Micro { block: 4 }],
        ..ServeOptions::default()
    }
}

fn micro_trace(requests: usize, seed: u64) -> Vec<Request> {
    serve::synth_trace(
        &ArrivalSpec::Poisson { rate_per_s: 500.0 },
        &["micro@4".to_string()],
        requests,
        seed,
    )
    .unwrap()
}

/// The acceptance headline: a fixed seed produces byte-identical
/// `ServeReport` JSON — and identical batch compositions — for
/// `--jobs 1` and `--jobs 4`.
#[test]
fn report_is_byte_identical_across_worker_counts() {
    let trace = micro_trace(48, 7);
    let mut serial_opts = micro_opts();
    serial_opts.jobs = 1;
    let mut parallel_opts = micro_opts();
    parallel_opts.jobs = 4;
    let serial = serve::run(&serial_opts, &trace).unwrap();
    let parallel = serve::run(&parallel_opts, &trace).unwrap();
    assert_eq!(
        serial.batches, parallel.batches,
        "batch compositions must not depend on the worker count"
    );
    assert_eq!(
        schedule_digest(&serial.batches),
        schedule_digest(&parallel.batches)
    );
    assert_eq!(
        serial.report.to_json().to_string_pretty(),
        parallel.report.to_json().to_string_pretty(),
        "ServeReport JSON must be byte-identical across --jobs 1 and --jobs 4"
    );
    assert_eq!(serial.report.completed, 48);
}

/// Replaying an archived trace reproduces the synthetic run exactly.
#[test]
fn replayed_trace_reproduces_the_run() {
    let trace = micro_trace(24, 11);
    let path = std::env::temp_dir()
        .join(format!("vta_serve_replay_{}.jsonl", std::process::id()));
    serve::write_trace(&path, &trace).unwrap();
    let replayed = serve::read_trace(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let opts = micro_opts();
    let a = serve::run(&opts, &trace).unwrap();
    let b = serve::run(&opts, &replayed).unwrap();
    assert_eq!(
        a.report.to_json().to_string_pretty(),
        b.report.to_json().to_string_pretty()
    );
}

/// Warm-pool amortization: the first evaluation per workload simulates,
/// every served request after it replays the memo, and the cycle
/// accounting stays exact.
#[test]
fn warm_pool_amortizes_and_accounts_cycles_exactly() {
    let opts = micro_opts();
    // Arrivals much denser than the 2000us batching window, so
    // coalescing is certain (~40 arrivals per window on average).
    let trace = serve::synth_trace(
        &ArrivalSpec::Poisson { rate_per_s: 20_000.0 },
        &["micro@4".to_string()],
        32,
        3,
    )
    .unwrap();
    let outcome = serve::run(&opts, &trace).unwrap();
    let r = &outcome.report;
    assert_eq!(r.completed, 32);
    assert!(r.memo_hits > 0, "served requests must hit the warm memo");
    let per_req = r.workloads["micro@4"].cycles_per_request;
    assert!(per_req > 0);
    assert_eq!(
        r.total_cycles,
        32 * per_req,
        "cycles are data-independent: every request costs the warm amount"
    );
    // The batcher actually batched: fewer dispatches than requests.
    assert!(r.batches_dispatched < 32, "dense arrivals must coalesce");
    assert!(r.mean_batch_occupancy > 1.0);
}

/// A mixed pool serves both workloads and never mixes them in a batch.
#[test]
fn mixed_workload_pool_batches_separately() {
    let mut opts = micro_opts();
    opts.workloads =
        vec![WorkloadSpec::Micro { block: 4 }, WorkloadSpec::Micro { block: 8 }];
    let trace = serve::synth_trace(
        &ArrivalSpec::Poisson { rate_per_s: 500.0 },
        &["micro@4".to_string(), "micro@8".to_string()],
        32,
        5,
    )
    .unwrap();
    let outcome = serve::run(&opts, &trace).unwrap();
    assert_eq!(outcome.report.completed, 32);
    assert_eq!(outcome.report.workloads.len(), 2);
    for batch in &outcome.batches {
        for &i in &batch.requests {
            assert_eq!(trace[i].workload, batch.workload, "batches never mix workloads");
        }
    }
}

/// Mixed workload *families* (attention, LSTM, CNN) share a pool but
/// never co-batch, and both new families serve cleanly — no shed, no
/// expiry — on the default configuration.
#[test]
fn mixed_family_pool_never_mixes_batches_and_serves_cleanly() {
    let mut opts = micro_opts();
    opts.cfg = presets::default_config();
    opts.workloads = vec![
        WorkloadSpec::Transformer { seq: 8 },
        WorkloadSpec::Lstm { seq: 8 },
        WorkloadSpec::Micro { block: 16 },
    ];
    let names: Vec<String> = ["transformer_block@8", "lstm_cell@8", "micro@16"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let trace =
        serve::synth_trace(&ArrivalSpec::Poisson { rate_per_s: 500.0 }, &names, 30, 17).unwrap();
    let outcome = serve::run(&opts, &trace).unwrap();
    let r = &outcome.report;
    assert_eq!(r.rejected_queue_full, 0, "default config must not shed the mixed trace");
    assert_eq!(r.expired_deadline, 0, "default config must not expire the mixed trace");
    assert_eq!(r.completed, 30);
    assert_eq!(r.workloads.len(), 3);
    for batch in &outcome.batches {
        for &i in &batch.requests {
            assert_eq!(trace[i].workload, batch.workload, "batches never mix families");
        }
    }
    for name in &names {
        assert!(r.workloads[name].cycles_per_request > 0, "{name} was never priced");
    }
}

/// Overload sheds at the bounded queue — with exact, loss-free
/// accounting. (Deadline expiry, which itself sheds load and therefore
/// keeps the queue short, is exercised separately below.)
#[test]
fn overload_sheds_at_the_bounded_queue() {
    let mut opts = micro_opts();
    opts.max_batch = 1;
    opts.max_wait_us = 0;
    opts.queue_depth = 4;
    // A burst far faster than the service rate.
    let trace: Vec<Request> = (0..64)
        .map(|i| Request { t_us: i, workload: "micro@4".to_string(), seed: i })
        .collect();
    let outcome = serve::run(&opts, &trace).unwrap();
    let r = &outcome.report;
    assert!(r.rejected_queue_full > 0, "the bounded queue must shed");
    assert_eq!(
        r.completed + r.rejected_queue_full + r.expired_deadline,
        r.submitted,
        "every request is completed, shed, or expired — never lost"
    );
    assert!(r.max_queue_depth <= opts.queue_depth);
}

/// Backlogged requests whose deadline passes before their batch starts
/// expire at dispatch instead of wasting device time.
#[test]
fn backlog_expires_past_deadline_requests() {
    let mut opts = micro_opts();
    opts.max_batch = 1;
    opts.max_wait_us = 0;
    opts.deadline_us = Some(100);
    let trace: Vec<Request> = (0..64)
        .map(|i| Request { t_us: i, workload: "micro@4".to_string(), seed: i })
        .collect();
    let outcome = serve::run(&opts, &trace).unwrap();
    let r = &outcome.report;
    assert!(r.expired_deadline > 0, "a backlog past the deadline must expire requests");
    assert!(r.completed > 0, "the head of the burst still completes");
    assert_eq!(r.completed + r.rejected_queue_full + r.expired_deadline, r.submitted);
    // Expired requests consumed no device time.
    let per_req = r.workloads["micro@4"].cycles_per_request;
    assert_eq!(r.total_cycles, r.completed as u64 * per_req);
}

/// The typed rejection surface of the serving layer.
#[test]
fn rejections_are_typed_vta_errors() {
    // fsim produces no cycles: the pool cannot price requests.
    let mut opts = micro_opts();
    opts.backend = BackendKind::Fsim;
    let err = serve::run(&opts, &[]).unwrap_err();
    assert!(matches!(err, VtaError::Unsupported(_)), "got {err:?}");

    // A trace naming an unpooled workload does not fit the pool.
    let opts = micro_opts();
    let ghost =
        [Request { t_us: 0, workload: "resnet18@224".to_string(), seed: 1 }];
    let err = serve::run(&opts, &ghost).unwrap_err();
    assert!(matches!(err, VtaError::InvalidRequest(_)), "got {err:?}");

    // Nonsensical scheduler options.
    let mut opts = micro_opts();
    opts.max_batch = 0;
    let err = serve::run(&opts, &micro_trace(2, 1)).unwrap_err();
    assert!(matches!(err, VtaError::InvalidRequest(_)), "got {err:?}");

    // A malformed arrival spec never reaches the runtime.
    let err = ArrivalSpec::parse("burst:10").unwrap_err();
    assert!(matches!(err, VtaError::InvalidRequest(_)), "got {err:?}");

    // A missing replay file is an invalid request naming the path, not
    // a bare I/O error.
    let err = serve::read_trace(std::path::Path::new("/no/such/replay.jsonl")).unwrap_err();
    assert!(matches!(err, VtaError::InvalidRequest(_)), "got {err:?}");
    assert!(err.to_string().contains("/no/such/replay.jsonl"), "got {err}");

    // An invalid hardware configuration fails with the config taxonomy.
    let mut opts = micro_opts();
    opts.cfg.axi_bytes = 3;
    let err = SessionPool::build(&opts).unwrap_err();
    assert!(matches!(err, VtaError::Config(_)), "got {err:?}");
}

/// Malformed `--arrival` specs surface as typed `InvalidRequest` errors
/// quoting the offending token, so the CLI message names exactly what
/// was typed.
#[test]
fn malformed_arrival_specs_quote_the_offending_token() {
    for bad in ["poisson", "poisson:fast", "uniform:0", "uniform:-3", "burst:9"] {
        let err = ArrivalSpec::parse(bad).unwrap_err();
        assert!(matches!(err, VtaError::InvalidRequest(_)), "'{bad}': got {err:?}");
        assert!(err.to_string().contains(bad), "'{bad}' must appear in: {err}");
    }
}

/// The functional rungs serve too (with bit-exact outputs via memo
/// replay); the report stays deterministic per rung.
#[test]
fn functional_tsim_serves_deterministically() {
    let mut opts = micro_opts();
    opts.backend = BackendKind::Tsim;
    let trace = micro_trace(8, 9);
    let a = serve::run(&opts, &trace).unwrap();
    let b = serve::run(&opts, &trace).unwrap();
    assert_eq!(
        a.report.to_json().to_string_pretty(),
        b.report.to_json().to_string_pretty()
    );
    assert_eq!(a.report.completed, 8);
}

/// The analytical rung serves instantly: same scheduler, predicted
/// service times.
#[test]
fn analytical_backend_serves() {
    let mut opts = micro_opts();
    opts.backend = BackendKind::Analytical;
    let trace = micro_trace(16, 13);
    let outcome = serve::run(&opts, &trace).unwrap();
    assert_eq!(outcome.report.completed, 16);
    assert_eq!(outcome.report.memo_hits, 0, "the model pool has no layer memo");
    assert!(outcome.report.total_cycles > 0);
}

/// `max_wait_us` bounds the co-batching delay of an unloaded system:
/// no admitted request waits longer than window + overhead + service.
#[test]
fn max_wait_bounds_unloaded_latency() {
    let mut opts = micro_opts();
    opts.max_batch = 64; // never fills: the window is the only trigger
    opts.max_wait_us = 500;
    // Arrivals far apart: the device is always idle at dispatch.
    let trace: Vec<Request> = (0..6)
        .map(|i| Request { t_us: i * 10_000_000, workload: "micro@4".to_string(), seed: i })
        .collect();
    let outcome = serve::run(&opts, &trace).unwrap();
    let r = &outcome.report;
    let service = r.workloads["micro@4"].service_us;
    let bound = (opts.max_wait_us + opts.dispatch_overhead_us + service) as f64;
    assert_eq!(r.completed, 6);
    assert!(
        r.latency_max_us as f64 <= bound,
        "unloaded latency {} must respect the window bound {}",
        r.latency_max_us,
        bound
    );
}
