//! Fleet-serving acceptance tests: the determinism contract extended to
//! heterogeneous fleets (routing and autoscaling are part of the
//! virtual-time model, so worker count never changes a `FleetReport`),
//! loss-free request accounting across devices, routing monotonicity,
//! the `RoutePolicy` misbehavior contract, and the cost-vs-SLO
//! frontier's shape.

use std::collections::BTreeMap;
use vta::config::{presets, VtaConfig};
use vta::serve::{
    self, schedule_fleet, CheapestFirst, DeviceCost, EarliestFeasibleCheapest, FleetOptions,
    FleetReport, LaneView, LeastLoaded, Request, RoutePolicy, RoutePolicyKind, SchedOptions,
    ServeOptions,
};
use vta::sweep::WorkloadSpec;
use vta::util::json::Json;

fn fleet_opts(configs: Vec<VtaConfig>) -> FleetOptions {
    FleetOptions {
        base: ServeOptions {
            cfg: presets::tiny_config(),
            workloads: vec![WorkloadSpec::Micro { block: 4 }],
            ..ServeOptions::default()
        },
        configs,
        policy: RoutePolicyKind::EarliestFeasibleCheapest,
        autoscale: None,
    }
}

fn micro_burst(n: u64, gap_us: u64) -> Vec<Request> {
    (0..n)
        .map(|i| Request { t_us: (i / 4) * gap_us, workload: "micro@4".into(), seed: i })
        .collect()
}

fn svc(us: u64) -> BTreeMap<String, u64> {
    [("w".to_string(), us)].into_iter().collect()
}

fn sched_opts(max_batch: usize, queue_depth: usize) -> SchedOptions {
    SchedOptions {
        max_batch,
        max_wait_us: 0,
        queue_depth,
        deadline_us: None,
        dispatch_overhead_us: 0,
    }
}

fn p99(latencies_us: &[(usize, u64)]) -> u64 {
    let mut v: Vec<u64> = latencies_us.iter().map(|&(_, l)| l).collect();
    v.sort_unstable();
    v[(v.len() * 99).div_ceil(100) - 1]
}

/// The acceptance headline: routing and autoscaling decisions live in
/// virtual time, so `FleetReport` JSON — and the batch schedule and lane
/// lifetimes behind it — are byte-identical across `--jobs 1` and
/// `--jobs 4`.
#[test]
fn fleet_report_is_byte_identical_across_worker_counts() {
    let trace = micro_burst(32, 25);
    let mut serial =
        fleet_opts(vec![presets::tiny_config(), presets::scaled_config(1, 4, 4, 2, 32)]);
    serial.base.jobs = 1;
    let mut parallel = serial.clone();
    parallel.base.jobs = 4;
    let a = serve::run_fleet(&serial, &trace).unwrap();
    let b = serve::run_fleet(&parallel, &trace).unwrap();
    assert_eq!(a.batches, b.batches, "batch schedule must not depend on the worker count");
    assert_eq!(a.lanes, b.lanes, "lane lifetimes must not depend on the worker count");
    assert_eq!(
        a.report.to_json().to_string_pretty(),
        b.report.to_json().to_string_pretty(),
        "FleetReport JSON must be byte-identical across --jobs 1 and --jobs 4"
    );
}

/// The determinism contract holds for mixed workload families too: a
/// fleet serving a transformer + CNN trace produces byte-identical
/// reports for `--jobs 1` and `--jobs 4`.
#[test]
fn mixed_family_fleet_report_is_byte_identical_across_worker_counts() {
    let mut serial =
        fleet_opts(vec![presets::tiny_config(), presets::scaled_config(1, 4, 4, 2, 32)]);
    serial.base.workloads =
        vec![WorkloadSpec::Transformer { seq: 8 }, WorkloadSpec::Micro { block: 4 }];
    serial.base.jobs = 1;
    let mut parallel = serial.clone();
    parallel.base.jobs = 4;
    let trace: Vec<Request> = (0..24u64)
        .map(|i| Request {
            t_us: (i / 2) * 40,
            workload: if i % 2 == 0 { "transformer_block@8".into() } else { "micro@4".into() },
            seed: i,
        })
        .collect();
    let a = serve::run_fleet(&serial, &trace).unwrap();
    let b = serve::run_fleet(&parallel, &trace).unwrap();
    assert_eq!(a.batches, b.batches, "batch schedule must not depend on the worker count");
    assert_eq!(a.lanes, b.lanes, "lane lifetimes must not depend on the worker count");
    assert_eq!(
        a.report.to_json().to_string_pretty(),
        b.report.to_json().to_string_pretty(),
        "mixed-family FleetReport JSON must be byte-identical across --jobs 1 and --jobs 4"
    );
    assert_eq!(a.report.completed, 24, "both families must serve to completion");
}

/// Every submitted request lands in exactly one bucket — completed on
/// some device, shed, or expired — and the per-device counters add back
/// up to the fleet totals.
#[test]
fn accounting_is_loss_free_across_devices_under_shedding() {
    let mut opts =
        fleet_opts(vec![presets::tiny_config(), presets::scaled_config(1, 4, 4, 2, 32)]);
    opts.base.max_batch = 1;
    opts.base.max_wait_us = 0;
    opts.base.queue_depth = 3;
    opts.policy = RoutePolicyKind::LeastLoaded;
    // 24 simultaneous arrivals vs 2 devices x queue 3: most must shed.
    let trace: Vec<Request> =
        (0..24u64).map(|i| Request { t_us: 0, workload: "micro@4".into(), seed: i }).collect();
    let r = serve::run_fleet(&opts, &trace).unwrap().report;
    assert!(r.rejected_queue_full > 0, "the burst must overflow both queues");
    assert_eq!(
        r.completed + r.rejected_queue_full + r.expired_deadline,
        r.submitted,
        "completed + shed + expired must equal submitted"
    );
    assert_eq!(r.admitted + r.rejected_queue_full, r.submitted);
    assert_eq!(r.devices.iter().map(|d| d.routed).sum::<usize>(), r.admitted);
    assert_eq!(r.devices.iter().map(|d| d.completed).sum::<usize>(), r.completed);
}

/// Adding a strictly faster (higher-area) device never worsens p99
/// under the same trace, for every built-in policy. With no deadline the
/// cost-greedy policies keep everything on the cheap device (equality);
/// least-loaded must actually improve.
#[test]
fn adding_a_strictly_faster_device_never_worsens_p99() {
    let slow = DeviceCost { config: "slow".into(), service_us: svc(500), scaled_area: 1.0 };
    let fast = DeviceCost { config: "fast".into(), service_us: svc(100), scaled_area: 4.0 };
    let trace: Vec<Request> =
        (0..64u64).map(|i| Request { t_us: i * 50, workload: "w".into(), seed: i }).collect();
    let opts = sched_opts(1, 10_000);
    let single = schedule_fleet(&trace, &[slow.clone()], &LeastLoaded, &opts, None).unwrap();
    let single_p99 = p99(&single.schedule.latencies_us);
    let pair = [slow, fast];
    let policies: [&dyn RoutePolicy; 3] =
        [&EarliestFeasibleCheapest, &LeastLoaded, &CheapestFirst];
    for policy in policies {
        let fleet = schedule_fleet(&trace, &pair, policy, &opts, None).unwrap();
        assert_eq!(fleet.schedule.completed(), trace.len(), "no deadline, huge queue");
        let fleet_p99 = p99(&fleet.schedule.latencies_us);
        assert!(
            fleet_p99 <= single_p99,
            "policy {}: fleet p99 {fleet_p99} worse than single-device p99 {single_p99}",
            policy.name()
        );
    }
    let balanced = schedule_fleet(&trace, &pair, &LeastLoaded, &opts, None).unwrap();
    assert!(
        p99(&balanced.schedule.latencies_us) < single_p99,
        "least-loaded must exploit the fast device"
    );
}

/// A policy that never returns an offered lane: the contract says every
/// such request is shed, not panicked on or lost.
struct Stonewall;

impl RoutePolicy for Stonewall {
    fn name(&self) -> &'static str {
        "stonewall"
    }

    fn route(&self, _now_us: u64, _deadline_us: Option<u64>, _lanes: &[LaneView]) -> usize {
        usize::MAX
    }
}

#[test]
fn a_policy_returning_an_unoffered_lane_sheds_instead_of_panicking() {
    let dev = DeviceCost { config: "a".into(), service_us: svc(10), scaled_area: 1.0 };
    let trace: Vec<Request> =
        (0..4u64).map(|i| Request { t_us: i, workload: "w".into(), seed: i }).collect();
    let fs = schedule_fleet(&trace, &[dev], &Stonewall, &sched_opts(1, 8), None).unwrap();
    assert_eq!(fs.schedule.admitted, 0);
    assert_eq!(fs.schedule.rejected_queue_full.len(), 4, "every arrival shed, none lost");
}

/// Strict schema round trip, `ExecCounters::from_json` style: exact
/// field set, exact `schema_version`, float-exact values.
#[test]
fn fleet_report_json_roundtrips_strictly() {
    let opts = fleet_opts(vec![presets::tiny_config(), presets::scaled_config(1, 4, 4, 2, 32)]);
    let trace = micro_burst(12, 40);
    let report = serve::run_fleet(&opts, &trace).unwrap().report;
    let parsed = Json::parse(&report.to_json().to_string_pretty()).unwrap();
    assert_eq!(FleetReport::from_json(&parsed), Some(report.clone()));
    let j = report.to_json();
    if let Json::Object(mut map) = j.clone() {
        map.insert("wall_ns".into(), Json::Int(1));
        assert_eq!(FleetReport::from_json(&Json::Object(map)), None, "unknown field");
    }
    if let Json::Object(mut map) = j.clone() {
        map.remove("peak_area");
        assert_eq!(FleetReport::from_json(&Json::Object(map)), None, "missing field");
    }
    if let Json::Object(mut map) = j {
        map.insert("schema_version".into(), Json::Int(0));
        assert_eq!(FleetReport::from_json(&Json::Object(map)), None, "wrong schema version");
    }
}

/// The frontier runs every single-device candidate plus the combined
/// fleet over the same trace, marks the `(peak_area, p99)` Pareto
/// survivors, and under queue pressure the fleet completes at least as
/// much as the best single device.
#[test]
fn frontier_covers_every_candidate_and_fleet_dominates_under_overload() {
    let mut opts = fleet_opts(vec![
        presets::tiny_config(),
        presets::scaled_config(1, 4, 4, 2, 32),
        presets::scaled_config(1, 4, 4, 2, 64),
    ]);
    opts.base.max_batch = 2;
    opts.base.queue_depth = 4;
    let trace = micro_burst(48, 20);
    let outcome = serve::frontier(&opts, &trace).unwrap();
    assert_eq!(outcome.entries.len(), 4, "3 single-device candidates + the combined fleet");
    let fleet = outcome.entries.iter().find(|e| e.label == "fleet(3)").expect("fleet entry");
    assert_eq!(fleet.configs.len(), 3);
    assert!(outcome.entries.iter().any(|e| e.pareto), "a nonempty set has Pareto survivors");
    let singles = outcome.entries.iter().filter(|e| e.label != "fleet(3)");
    let best_single = singles.map(|e| e.report.completed).max().unwrap();
    assert!(
        fleet.report.completed >= best_single,
        "under queue pressure the fleet must not complete less than the best single device"
    );
    let j = outcome.to_json();
    assert_eq!(j.get("schema_version").and_then(|v| v.as_i64()), Some(1));
    let entries = j.get("entries").and_then(|e| e.as_array()).map(|a| a.len());
    assert_eq!(entries, Some(4));
}
