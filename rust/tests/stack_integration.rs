//! Cross-layer integration tests: the full compile → stage → simulate
//! pipeline must agree bit-exactly with the CPU reference model, on both
//! simulator targets, across schedules and configurations.

use vta::compiler::graph::{Graph, Op};
use vta::compiler::layout::Shape;
use vta::config::presets;
use vta::engine::BackendKind;
use vta::runtime::{Session, SessionOptions};
use vta::util::rng::Pcg32;
use vta::workloads;

fn run_both(graph: &Graph, cfg: &vta::config::VtaConfig, opts: SessionOptions, seed: u64) {
    let mut rng = Pcg32::seeded(seed);
    let input = rng.i8_vec(cfg.batch * graph.input_shape.elems());
    let expect = graph.run_cpu(&input, cfg.batch);

    let mut fs =
        Session::new(cfg, SessionOptions { backend: BackendKind::Fsim, ..opts.clone() })
            .unwrap();
    let got_f = fs.run_graph(graph, &input).unwrap();
    assert_eq!(got_f, expect, "fsim output != cpu reference ({})", graph.name);

    let mut ts =
        Session::new(cfg, SessionOptions { backend: BackendKind::Tsim, ..opts }).unwrap();
    let got_t = ts.run_graph(graph, &input).unwrap();
    assert_eq!(got_t, expect, "tsim output != cpu reference ({})", graph.name);
    assert!(ts.cycles() > 0);
}

#[test]
fn single_conv_layer_tiny() {
    let cfg = presets::tiny_config();
    let mut rng = Pcg32::seeded(3);
    let mut g = Graph::new("conv-only", Shape::new(4, 8, 8));
    g.add(
        "conv",
        Op::Conv {
            c_out: 8,
            k: 3,
            stride: 1,
            pad: 1,
            shift: 4,
            relu: true,
            weights: rng.i8_vec(8 * 4 * 9),
        },
        vec![0],
    );
    run_both(&g, &cfg, SessionOptions::default(), 10);
}

#[test]
fn conv_stride2_no_pad() {
    let cfg = presets::tiny_config();
    let mut rng = Pcg32::seeded(4);
    let mut g = Graph::new("conv-s2", Shape::new(8, 9, 9));
    g.add(
        "conv",
        Op::Conv {
            c_out: 4,
            k: 3,
            stride: 2,
            pad: 0,
            shift: 4,
            relu: false,
            weights: rng.i8_vec(4 * 8 * 9),
        },
        vec![0],
    );
    run_both(&g, &cfg, SessionOptions::default(), 11);
}

#[test]
fn conv_1x1() {
    let cfg = presets::tiny_config();
    let mut rng = Pcg32::seeded(5);
    let mut g = Graph::new("conv-1x1", Shape::new(8, 6, 6));
    g.add(
        "conv",
        Op::Conv {
            c_out: 8,
            k: 1,
            stride: 1,
            pad: 0,
            shift: 3,
            relu: true,
            weights: rng.i8_vec(8 * 8),
        },
        vec![0],
    );
    run_both(&g, &cfg, SessionOptions::default(), 12);
}

#[test]
fn conv_fallback_schedule_matches_too() {
    let cfg = presets::tiny_config();
    let mut rng = Pcg32::seeded(6);
    let mut g = Graph::new("conv-fb", Shape::new(8, 8, 8));
    g.add(
        "conv",
        Op::Conv {
            c_out: 8,
            k: 3,
            stride: 1,
            pad: 1,
            shift: 4,
            relu: true,
            weights: rng.i8_vec(8 * 8 * 9),
        },
        vec![0],
    );
    run_both(&g, &cfg, SessionOptions { tps: false, ..Default::default() }, 13);
}

#[test]
fn conv_without_dbuf_reuse_matches() {
    let cfg = presets::tiny_config();
    let mut rng = Pcg32::seeded(7);
    let mut g = Graph::new("conv-nodbuf", Shape::new(8, 8, 8));
    g.add(
        "conv",
        Op::Conv {
            c_out: 16,
            k: 3,
            stride: 1,
            pad: 1,
            shift: 4,
            relu: true,
            weights: rng.i8_vec(16 * 8 * 9),
        },
        vec![0],
    );
    run_both(&g, &cfg, SessionOptions { dbuf_reuse: false, ..Default::default() }, 14);
}

#[test]
fn micro_resnet_end_to_end() {
    let cfg = presets::tiny_config();
    let g = workloads::micro_resnet(4, 42);
    run_both(&g, &cfg, SessionOptions::default(), 15);
}

#[test]
fn micro_mobilenet_end_to_end() {
    let cfg = presets::tiny_config();
    let g = workloads::micro_mobilenet(4, 43);
    run_both(&g, &cfg, SessionOptions::default(), 16);
}

#[test]
fn micro_resnet_on_default_config() {
    let cfg = presets::default_config();
    let g = workloads::micro_resnet(16, 44);
    run_both(&g, &cfg, SessionOptions::default(), 17);
}

#[test]
fn micro_resnet_unpipelined() {
    let cfg = presets::original_config();
    let g = workloads::micro_resnet(16, 45);
    run_both(&g, &cfg, SessionOptions::default(), 18);
}

#[test]
fn batch2_config() {
    let mut cfg = presets::tiny_config();
    cfg.batch = 2;
    let g = workloads::micro_resnet(4, 46);
    run_both(&g, &cfg, SessionOptions::default(), 19);
}
