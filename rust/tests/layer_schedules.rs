//! Per-schedule integration tests: each ALU-based layer kind (pooling,
//! residual add, depthwise) and structural variants (bottleneck blocks,
//! larger strides, ragged channel counts) verified bit-exactly against
//! the CPU reference on both simulator targets.

use vta::compiler::graph::{Graph, Op};
use vta::compiler::layout::Shape;
use vta::config::presets;
use vta::engine::BackendKind;
use vta::runtime::{Session, SessionOptions};
use vta::util::rng::Pcg32;

fn check(graph: &Graph, seed: u64) {
    let cfg = presets::tiny_config();
    let mut rng = Pcg32::seeded(seed);
    let input = rng.i8_vec(cfg.batch * graph.input_shape.elems());
    let expect = graph.run_cpu(&input, cfg.batch);
    for backend in [BackendKind::Fsim, BackendKind::Tsim] {
        let mut s = Session::new(&cfg, SessionOptions { backend, ..Default::default() }).unwrap();
        let got = s.run_graph(graph, &input).unwrap();
        assert_eq!(got, expect, "{backend:?} mismatch for {}", graph.name);
    }
}

#[test]
fn maxpool_3x3_stride2_padded() {
    let mut g = Graph::new("pool-3x3", Shape::new(8, 9, 9));
    g.add("pool", Op::MaxPool { k: 3, stride: 2, pad: 1 }, vec![0]);
    check(&g, 1);
}

#[test]
fn maxpool_2x2_stride2() {
    let mut g = Graph::new("pool-2x2", Shape::new(4, 8, 8));
    g.add("pool", Op::MaxPool { k: 2, stride: 2, pad: 0 }, vec![0]);
    check(&g, 2);
}

#[test]
fn global_avgpool_7x7() {
    let mut g = Graph::new("gap", Shape::new(8, 7, 7));
    g.add("gap", Op::GlobalAvgPool, vec![0]);
    check(&g, 3);
}

#[test]
fn residual_add_with_relu() {
    let mut rng = Pcg32::seeded(4);
    let mut g = Graph::new("residual", Shape::new(4, 6, 6));
    let c = g.add(
        "conv",
        Op::Conv { c_out: 4, k: 3, stride: 1, pad: 1, shift: 4, relu: false, weights: rng.i8_vec(4 * 4 * 9) },
        vec![0],
    );
    g.add("add", Op::Add { relu: true }, vec![c, 0]);
    check(&g, 5);
}

#[test]
fn residual_add_large_tile_count() {
    // Enough tiles to force multiple chunks through the add schedule.
    let mut rng = Pcg32::seeded(6);
    let mut g = Graph::new("residual-big", Shape::new(8, 16, 16));
    let c = g.add(
        "conv",
        Op::Conv { c_out: 8, k: 1, stride: 1, pad: 0, shift: 3, relu: false, weights: rng.i8_vec(8 * 8) },
        vec![0],
    );
    g.add("add", Op::Add { relu: false }, vec![c, 0]);
    check(&g, 7);
}

#[test]
fn depthwise_stride1_and_2() {
    for (seed, stride) in [(8u64, 1usize), (9, 2)] {
        let mut rng = Pcg32::seeded(seed);
        let mut g = Graph::new("dw", Shape::new(8, 9, 9));
        g.add(
            "dw",
            Op::Depthwise { k: 3, stride, pad: 1, shift: 3, relu: true, weights: rng.i8_vec(8 * 9) },
            vec![0],
        );
        check(&g, seed + 10);
    }
}

#[test]
fn depthwise_extreme_weights() {
    // Full-range int8 weights stress the 8-bit MUL truncation semantics.
    let mut rng = Pcg32::seeded(11);
    let mut g = Graph::new("dw-extreme", Shape::new(4, 6, 6));
    g.add(
        "dw",
        Op::Depthwise { k: 3, stride: 1, pad: 1, shift: 0, relu: false, weights: rng.i8_vec_full(4 * 9) },
        vec![0],
    );
    check(&g, 12);
}

#[test]
fn bottleneck_block() {
    // ResNet-50-style bottleneck: 1x1 reduce, 3x3, 1x1 expand + skip.
    let mut rng = Pcg32::seeded(13);
    let c = 4;
    let mut g = Graph::new("bottleneck", Shape::new(4 * c, 8, 8));
    let r = g.add(
        "reduce",
        Op::Conv { c_out: c, k: 1, stride: 1, pad: 0, shift: 4, relu: true, weights: rng.i8_vec(c * 4 * c) },
        vec![0],
    );
    let m = g.add(
        "mid",
        Op::Conv { c_out: c, k: 3, stride: 1, pad: 1, shift: 4, relu: true, weights: rng.i8_vec(c * c * 9) },
        vec![r],
    );
    let e = g.add(
        "expand",
        Op::Conv { c_out: 4 * c, k: 1, stride: 1, pad: 0, shift: 3, relu: false, weights: rng.i8_vec(4 * c * c) },
        vec![m],
    );
    g.add("add", Op::Add { relu: true }, vec![e, 0]);
    check(&g, 14);
}

#[test]
fn ragged_channel_count_padded() {
    // 5 channels with block 4: exercises channel zero-padding end to end.
    let mut rng = Pcg32::seeded(15);
    let mut g = Graph::new("ragged", Shape::new(5, 6, 6));
    g.add(
        "conv",
        // c_in = 5 > block 4, so the layer runs on the accelerator with
        // a zero-padded channel tail.
        Op::Conv { c_out: 8, k: 3, stride: 1, pad: 1, shift: 4, relu: true, weights: rng.i8_vec(8 * 5 * 9) },
        vec![0],
    );
    check(&g, 16);
}

#[test]
fn dense_after_gap() {
    let mut rng = Pcg32::seeded(17);
    let mut g = Graph::new("head", Shape::new(8, 4, 4));
    let gap = g.add("gap", Op::GlobalAvgPool, vec![0]);
    g.add(
        "fc",
        Op::Dense { units: 12, shift: 2, relu: false, weights: rng.i8_vec(12 * 8) },
        vec![gap],
    );
    check(&g, 18);
}

#[test]
fn deep_chain_of_mixed_layers() {
    // A longer mixed pipeline on the default (16-block) config.
    let cfg = presets::default_config();
    let g = vta::workloads::micro_mobilenet(16, 19);
    let mut rng = Pcg32::seeded(20);
    let input = rng.i8_vec(cfg.batch * g.input_shape.elems());
    let expect = g.run_cpu(&input, cfg.batch);
    let mut s = Session::new(&cfg, SessionOptions::default()).unwrap();
    assert_eq!(s.run_graph(&g, &input).unwrap(), expect);
}
