//! Residency-planner integration invariants (the contract that makes
//! the cross-layer scratchpad pass sound):
//!
//! * residency is *purely* a timing/counter optimization: network
//!   outputs are byte-identical across every mode × backend cell of a
//!   reduced grid, and fsim/tsim agree on every execution counter
//!   under the default (LRU) plan;
//! * the tentpole acceptance number: micro-ResNet under `--residency
//!   lru` takes strictly fewer tsim cycles than `--residency off`,
//!   with DMA bytes actually elided;
//! * Belady never spills more bytes than LRU on any `workloads::`
//!   network, and DTR never rematerializes a weight-bearing producer.

use vta::compiler::graph::Graph;
use vta::compiler::residency::{self, recomputable, ResidencyMode};
use vta::config::presets;
use vta::engine::BackendKind;
use vta::exec::ExecCounters;
use vta::runtime::{Session, SessionOptions};
use vta::util::rng::Pcg32;
use vta::workloads;

fn run(
    graph: &Graph,
    input: &[i8],
    cfg: &vta::config::VtaConfig,
    backend: BackendKind,
    residency: ResidencyMode,
) -> (Vec<i8>, u64, ExecCounters) {
    let opts = SessionOptions { backend, residency, ..Default::default() };
    let mut s = Session::new(cfg, opts).unwrap();
    let out = s.run_graph(graph, input).unwrap();
    (out, s.cycles(), s.exec_counters())
}

const MODES: [ResidencyMode; 4] =
    [ResidencyMode::Off, ResidencyMode::Lru, ResidencyMode::Belady, ResidencyMode::Dtr];

/// Outputs are bit-identical across every residency mode and backend:
/// eliding redirects counters, it never changes what executes. The
/// functional counters (instructions, MACs, ALU traffic) agree
/// everywhere except DTR, whose rematerialization reruns add layers.
#[test]
fn outputs_identical_across_modes_and_backends() {
    let cfg = presets::tiny_config();
    for graph in [
        workloads::micro_resnet(cfg.block_in, 3),
        workloads::micro_mobilenet(cfg.block_in, 4),
    ] {
        let mut rng = Pcg32::seeded(11);
        let input = rng.i8_vec(cfg.batch * graph.input_shape.elems());
        let (base_out, _, base_ctr) =
            run(&graph, &input, &cfg, BackendKind::Tsim, ResidencyMode::Off);
        for backend in [BackendKind::Fsim, BackendKind::Tsim] {
            for mode in MODES {
                let (out, _, ctr) = run(&graph, &input, &cfg, backend, mode);
                assert_eq!(
                    out, base_out,
                    "{}: {backend}/{} output differs from tsim/off",
                    graph.name,
                    mode.cli_name()
                );
                if mode != ResidencyMode::Dtr {
                    assert_eq!(ctr.insn_count, base_ctr.insn_count, "{}", graph.name);
                    assert_eq!(ctr.macs, base_ctr.macs, "{}", graph.name);
                    assert_eq!(ctr.alu_elems, base_ctr.alu_elems, "{}", graph.name);
                    // Eliding moves bytes between counters, it never
                    // loses them: cold + elided traffic is invariant
                    // (DTR is exempt — reruns add real traffic).
                    assert_eq!(
                        ctr.dram_bytes_total() + ctr.dma_bytes_elided,
                        base_ctr.dram_bytes_total(),
                        "{}: {backend}/{} byte conservation",
                        graph.name,
                        mode.cli_name()
                    );
                }
            }
        }
    }
}

/// fsim and tsim agree on every execution counter under the default
/// (LRU) plan — both derive the identical elision set from the pure
/// planner, so redirected bytes land identically.
#[test]
fn fsim_tsim_counter_parity_under_lru() {
    let cfg = presets::tiny_config();
    let graph = workloads::micro_resnet(cfg.block_in, 5);
    let mut rng = Pcg32::seeded(13);
    let input = rng.i8_vec(cfg.batch * graph.input_shape.elems());
    let (_, _, f) = run(&graph, &input, &cfg, BackendKind::Fsim, ResidencyMode::Lru);
    let (_, _, t) = run(&graph, &input, &cfg, BackendKind::Tsim, ResidencyMode::Lru);
    assert_eq!(f, t, "fsim and tsim must redirect the same bytes into the same counters");
    assert!(t.dma_bytes_elided > 0, "micro-ResNet has cross-layer reuse on tiny");
    assert!(t.resident_tile_hits > 0);
}

/// Tentpole acceptance: `--residency lru` is strictly faster than
/// `--residency off` on the micro-ResNet under tsim, with byte-identical
/// outputs (checked above) and traffic actually removed from the DMA
/// engine, not just recounted.
#[test]
fn lru_strictly_faster_than_off_on_micro_resnet_tsim() {
    let cfg = presets::tiny_config();
    let graph = workloads::micro_resnet(cfg.block_in, 3);
    let mut rng = Pcg32::seeded(17);
    let input = rng.i8_vec(cfg.batch * graph.input_shape.elems());
    let (out_off, cyc_off, ctr_off) =
        run(&graph, &input, &cfg, BackendKind::Tsim, ResidencyMode::Off);
    let (out_lru, cyc_lru, ctr_lru) =
        run(&graph, &input, &cfg, BackendKind::Tsim, ResidencyMode::Lru);
    assert_eq!(out_lru, out_off, "digests must not move");
    assert_eq!(ctr_off.dma_bytes_elided, 0, "off elides nothing");
    assert!(ctr_lru.dma_bytes_elided > 0, "lru must elide DMA traffic");
    assert!(
        ctr_lru.dram_bytes_total() < ctr_off.dram_bytes_total(),
        "elided bytes leave the DRAM-traffic total"
    );
    assert!(
        cyc_lru < cyc_off,
        "zero-occupancy elided transfers must save cycles: lru {cyc_lru} vs off {cyc_off}"
    );
}

/// Belady's clamped offline plan never spills more bytes than LRU, on
/// every network the workloads module can build.
#[test]
fn belady_spills_no_more_than_lru_on_every_workload() {
    let graphs = [
        workloads::micro_resnet(16, 1),
        workloads::micro_mobilenet(16, 1),
        workloads::resnet(18, 32, 1),
        workloads::resnet(34, 32, 1),
        workloads::resnet(50, 32, 1),
        workloads::resnet(101, 32, 1),
        workloads::mobilenet(32, 1),
    ];
    // Include scratchpads small enough to force eviction decisions.
    for depth in [64usize, 512, 2048] {
        let mut cfg = presets::default_config();
        cfg.inp_depth = depth;
        for g in &graphs {
            let shapes = g.shapes();
            let b =
                residency::plan(&cfg, g, &shapes, ResidencyMode::Belady, true, true).unwrap();
            let l = residency::plan(&cfg, g, &shapes, ResidencyMode::Lru, true, true).unwrap();
            assert!(
                b.spilled_bytes <= l.spilled_bytes,
                "{} @ inp_depth {depth}: belady spilled {} > lru {}",
                g.name,
                b.spilled_bytes,
                l.spilled_bytes
            );
        }
    }
}

/// DTR rematerializes residual adds only — never a conv/dense/depthwise
/// producer, whose rerun would re-DMA its whole weight tensor.
#[test]
fn dtr_never_recomputes_weight_bearing_producers() {
    let graphs = [
        workloads::micro_resnet(16, 1),
        workloads::micro_mobilenet(16, 1),
        workloads::resnet(18, 32, 1),
        workloads::mobilenet(32, 1),
    ];
    for depth in [64usize, 256, 2048] {
        let mut cfg = presets::default_config();
        cfg.inp_depth = depth;
        for g in &graphs {
            let p =
                residency::plan(&cfg, g, &g.shapes(), ResidencyMode::Dtr, true, true).unwrap();
            for q in p.recomputed_producers() {
                assert!(
                    recomputable(g, q),
                    "{} @ inp_depth {depth}: planned recompute of weight-bearing node {}",
                    g.name,
                    g.nodes[q].name
                );
            }
        }
    }
}
