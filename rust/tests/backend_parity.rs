//! Cross-backend parity: the engine's fidelity-ladder contract on a
//! reduced grid. The `Backend` trait makes these invariants a loop over
//! backend kinds instead of bespoke per-path glue:
//!
//! * every output-producing backend (fsim, functional tsim) produces a
//!   **bit-identical output digest** per design point;
//! * every tsim backend (functional, timing-only) produces **identical
//!   cycles** per design point;
//! * every evaluation honors its declared capabilities — no garbage in
//!   fields a backend claims not to produce.

use vta::compiler::residency::ResidencyMode;
use vta::config::presets;
use vta::engine::{BackendKind, Engine, EvalRequest, Evaluation, Fidelity, VtaError};
use vta::runtime::{Session, SessionOptions};
use vta::util::hash::Fnv;
use vta::util::prop::{gen_graph, Prop};
use vta::workloads;
use vta::{prop_assert, prop_assert_eq};

/// The reduced grid: tiny-geometry variants × the micro-ResNet (the
/// same shape the sweep-engine acceptance tests use).
fn reduced_grid() -> Vec<vta::config::VtaConfig> {
    let mut configs = Vec::new();
    for axi in [8usize, 16] {
        for scale in [1usize, 2] {
            let mut cfg = presets::tiny_config();
            cfg.name = format!("tiny-s{scale}-m{axi}");
            cfg.axi_bytes = axi;
            cfg.inp_depth *= scale;
            cfg.wgt_depth *= scale;
            cfg.acc_depth *= scale;
            configs.push(cfg);
        }
    }
    configs
}

fn digest(output: &[i8]) -> u64 {
    let mut h = Fnv::new();
    h.write_i8s(output);
    h.finish()
}

fn eval_kind(cfg: &vta::config::VtaConfig, kind: BackendKind, seed: u64) -> Evaluation {
    let engine = Engine::for_config(cfg).backend_kind(kind).build().unwrap();
    let graph = workloads::micro_resnet(cfg.block_in, 42);
    engine.run(&graph, &EvalRequest::seeded(seed)).unwrap()
}

/// The headline parity loop: one `Evaluation` per rung, compared
/// pairwise through the capabilities the rungs share.
#[test]
fn ladder_rungs_agree_on_shared_products() {
    for cfg in reduced_grid() {
        // The 3-line ladder walk the trait buys us:
        let evals: Vec<Evaluation> =
            BackendKind::ALL.iter().map(|&kind| eval_kind(&cfg, kind, 7)).collect();

        let out_digests: Vec<u64> =
            evals.iter().filter_map(|e| e.output.as_deref().map(digest)).collect();
        assert_eq!(out_digests.len(), 2, "fsim + functional tsim produce outputs");
        assert_eq!(
            out_digests[0], out_digests[1],
            "{}: output digests must be bit-identical across functional backends",
            cfg.name
        );

        let tsim_cycles: Vec<u64> = evals
            .iter()
            .filter(|e| e.fidelity >= Fidelity::TimingOnly && e.cycles.is_some())
            .filter_map(|e| e.cycles)
            .collect();
        assert_eq!(tsim_cycles.len(), 2, "timing-only + functional tsim produce cycles");
        assert_eq!(
            tsim_cycles[0], tsim_cycles[1],
            "{}: timing-only cycles must equal functional tsim cycles",
            cfg.name
        );

        // Counters are part of the timing contract too.
        let counter_pairs: Vec<_> = evals
            .iter()
            .filter(|e| {
                matches!(e.fidelity, Fidelity::TimingOnly | Fidelity::CycleAccurate)
            })
            .map(|e| e.counters)
            .collect();
        assert_eq!(counter_pairs.len(), 2);
        assert_eq!(counter_pairs[0], counter_pairs[1], "{}: tsim counters diverged", cfg.name);
    }
}

/// Differential fuzz harness: seeded random graphs (CNN and
/// attention/LSTM operator menus — see [`gen_graph`]) pin the ladder
/// contract over a far larger structural space than the fixed
/// workloads. For every generated graph, on every residency mode:
///
/// * fsim and functional tsim agree on the output digest;
/// * functional tsim and timing-only tsim agree on cycles *and*
///   counters;
/// * outputs are bit-identical across residency modes (planning is a
///   timing optimization, never a semantic one).
///
/// On failure the [`Prop`] runner prints the case seed and the shrunk
/// draw vector — rerun with `Prop::seed` to reproduce.
#[test]
fn fuzz_random_graphs_agree_across_backends_and_residency() {
    let cfg = presets::tiny_config();
    Prop::new("backend-parity-fuzz").cases(64).seed(0xd1ff).run(|g| {
        let graph = gen_graph(g, cfg.block_in);
        graph
            .validate()
            .map_err(|e| format!("generator produced an invalid graph: {e}"))?;
        let req = EvalRequest::seeded(g.usize(0, 1 << 20) as u64);
        let mut mode_digests: Vec<u64> = Vec::new();
        for mode in [ResidencyMode::Off, ResidencyMode::Lru, ResidencyMode::Dtr] {
            let mut evals = Vec::new();
            for &kind in BackendKind::ALL.iter() {
                let engine = Engine::for_config(&cfg)
                    .backend_kind(kind)
                    .residency(mode)
                    .build()
                    .map_err(|e| format!("{kind}/{mode:?}: build: {e}"))?;
                evals.push(engine.run(&graph, &req).map_err(|e| format!("{kind}/{mode:?}: {e}"))?);
            }
            let digests: Vec<u64> =
                evals.iter().filter_map(|e| e.output.as_deref().map(digest)).collect();
            prop_assert!(digests.len() == 2, "{mode:?}: expected 2 functional backends");
            prop_assert!(
                digests[0] == digests[1],
                "{mode:?}: fsim/tsim digest split: {:#018x} vs {:#018x}",
                digests[0],
                digests[1]
            );
            let timed: Vec<&Evaluation> = evals
                .iter()
                .filter(|e| {
                    matches!(e.fidelity, Fidelity::TimingOnly | Fidelity::CycleAccurate)
                })
                .collect();
            prop_assert!(timed.len() == 2, "{mode:?}: expected 2 tsim rungs");
            prop_assert_eq!(timed[0].cycles, timed[1].cycles);
            prop_assert_eq!(timed[0].counters, timed[1].counters);
            mode_digests.push(digests[0]);
        }
        prop_assert!(
            mode_digests.iter().all(|&d| d == mode_digests[0]),
            "residency modes changed the output: {mode_digests:?}"
        );
        Ok(())
    });
}

/// The two new workload families run end-to-end on every rung, with
/// bit-identical functional digests and tsim/timing cycle agreement —
/// on the tiny test geometry *and* the default 16×16 geometry (where
/// the attention GEMMs and softmax take the accelerator path).
#[test]
fn workload_families_agree_on_all_rungs() {
    let grids: [(vta::config::VtaConfig, Vec<vta::compiler::graph::Graph>); 2] = [
        (
            presets::tiny_config(),
            vec![workloads::transformer_block(16, 4, 8, 3), workloads::lstm_cell(8, 4, 3)],
        ),
        (
            presets::default_config(),
            vec![workloads::transformer_block(64, 4, 16, 1), workloads::lstm_cell(64, 16, 1)],
        ),
    ];
    for (cfg, graphs) in &grids {
        for graph in graphs {
            let evals: Vec<Evaluation> = BackendKind::ALL
                .iter()
                .map(|&kind| {
                    let engine =
                        Engine::for_config(cfg).backend_kind(kind).build().unwrap();
                    engine.run(graph, &EvalRequest::seeded(5)).unwrap_or_else(|e| {
                        panic!("{}/{kind}: {e}", graph.name)
                    })
                })
                .collect();
            let out: Vec<u64> =
                evals.iter().filter_map(|e| e.output.as_deref().map(digest)).collect();
            assert_eq!(out.len(), 2, "{}: fsim + functional tsim", graph.name);
            assert_eq!(out[0], out[1], "{}@{}: digest split", graph.name, cfg.name);
            let cyc: Vec<u64> = evals
                .iter()
                .filter(|e| {
                    matches!(e.fidelity, Fidelity::TimingOnly | Fidelity::CycleAccurate)
                })
                .filter_map(|e| e.cycles)
                .collect();
            assert_eq!(cyc.len(), 2, "{}: both tsim rungs time", graph.name);
            assert_eq!(cyc[0], cyc[1], "{}@{}: cycle split", graph.name, cfg.name);
        }
    }
}

/// Every evaluation matches the capabilities its backend declared.
#[test]
fn evaluations_honor_declared_capabilities() {
    let cfg = presets::tiny_config();
    for kind in BackendKind::ALL {
        let caps = kind.instantiate().capabilities();
        let eval = eval_kind(&cfg, kind, 9);
        assert_eq!(eval.fidelity, kind.fidelity());
        assert_eq!(eval.output.is_some(), caps.produces_outputs, "{kind}: output presence");
        assert_eq!(eval.cycles.is_some(), caps.produces_cycles, "{kind}: cycle presence");
        assert!(!eval.layer_stats.is_empty(), "{kind}: per-layer breakdown always present");
        if let Some(cycles) = eval.cycles {
            assert!(cycles > 0, "{kind}: cycle counts are positive");
            let layer_total: u64 = eval.layer_stats.iter().map(|l| l.cycles).sum();
            assert_eq!(layer_total, cycles, "{kind}: layer stats must sum to the total");
        }
    }
}

/// Identical seeds produce identical evaluations on every rung
/// (determinism is per-backend, not just per-simulator).
#[test]
fn evaluations_are_deterministic_per_rung() {
    let cfg = presets::tiny_config();
    for kind in BackendKind::ALL {
        let a = eval_kind(&cfg, kind, 11);
        let b = eval_kind(&cfg, kind, 11);
        assert_eq!(a.cycles, b.cycles, "{kind}: cycles must be deterministic");
        assert_eq!(
            a.output.as_deref().map(digest),
            b.output.as_deref().map(digest),
            "{kind}: outputs must be deterministic"
        );
    }
}

/// Batched evaluation is bit-identical to the per-request loop on every
/// rung: `eval_many` reuses one session (`Session::reset_for_reuse`),
/// and the reset must be indistinguishable from a fresh session —
/// cycles, outputs, counters and per-layer stats all match, request by
/// request.
#[test]
fn eval_many_matches_per_request_eval() {
    let cfg = presets::tiny_config();
    let graph = workloads::micro_resnet(cfg.block_in, 42);
    // Mixed seeds, with a repeat, so carry-over from any earlier request
    // in the batch would show up as a mismatch.
    let requests: Vec<EvalRequest> =
        [3u64, 7, 3, 11].iter().map(|&s| EvalRequest::seeded(s)).collect();
    for kind in BackendKind::ALL {
        let engine = Engine::for_config(&cfg).backend_kind(kind).build().unwrap();
        let prepared = engine.prepare(&graph).unwrap();
        let batched = engine.eval_many(&prepared, &requests).unwrap();
        assert_eq!(batched.len(), requests.len());
        for (b, r) in batched.iter().zip(&requests) {
            let single = engine.eval(&prepared, r).unwrap();
            assert_eq!(b.cycles, single.cycles, "{kind}: batched cycles diverged");
            assert_eq!(
                b.output.as_deref().map(digest),
                single.output.as_deref().map(digest),
                "{kind}: batched output diverged"
            );
            assert_eq!(b.counters, single.counters, "{kind}: batched counters diverged");
            assert_eq!(b.layer_stats.len(), single.layer_stats.len());
            for (bl, sl) in b.layer_stats.iter().zip(&single.layer_stats) {
                assert_eq!(
                    (bl.cycles, bl.insns, bl.uops, bl.macs),
                    (sl.cycles, sl.insns, sl.uops, sl.macs),
                    "{kind}: layer stat {} diverged",
                    bl.name
                );
            }
        }
    }
    // The shared-prepared path routes through the same override.
    let engine = Engine::for_config(&cfg).backend_kind(BackendKind::Tsim).build().unwrap();
    let shared = engine.prepare_shared(std::sync::Arc::new(graph)).unwrap();
    let batched = engine.eval_many_shared(&shared, &requests).unwrap();
    let singles: Vec<Evaluation> =
        requests.iter().map(|r| engine.eval_shared(&shared, r).unwrap()).collect();
    for (b, s) in batched.iter().zip(&singles) {
        assert_eq!(b.cycles, s.cycles);
        assert_eq!(b.output.as_deref().map(digest), s.output.as_deref().map(digest));
    }
}

/// Malformed inputs fail with typed errors — never panics — at every
/// rung, through both the engine and the raw session.
#[test]
fn malformed_inputs_return_typed_errors_everywhere() {
    let cfg = presets::tiny_config();
    let graph = workloads::micro_resnet(cfg.block_in, 42);
    for kind in BackendKind::ALL {
        let engine = Engine::for_config(&cfg).backend_kind(kind).build().unwrap();
        let err = engine.run(&graph, &EvalRequest::with_data(vec![1, 2, 3])).unwrap_err();
        assert!(matches!(err, VtaError::InvalidRequest(_)), "{kind}: got {err:?}");
    }
    // Malformed graph: an Add with a single operand.
    let mut bad = vta::compiler::graph::Graph::new(
        "bad",
        vta::compiler::layout::Shape::new(cfg.block_in, 4, 4),
    );
    bad.add("add", vta::compiler::graph::Op::Add { relu: false }, vec![0]);
    let engine = Engine::for_config(&cfg).build().unwrap();
    assert!(matches!(engine.prepare(&bad), Err(VtaError::Graph(_))));
    let mut session = Session::new(&cfg, SessionOptions::default()).unwrap();
    assert!(matches!(session.run_graph(&bad, &[]), Err(VtaError::Graph(_))));
    // A session cannot host the analytical backend.
    assert!(matches!(
        Session::new(
            &cfg,
            SessionOptions { backend: BackendKind::Analytical, ..Default::default() }
        ),
        Err(VtaError::Unsupported(_))
    ));
}
