//! Failure-injection tests: deliberately break the stack and check the
//! validation machinery catches and localizes the defects — the paper's
//! §III-C claim that trace-based validation "was found to be very
//! effective at quickly locating defects".

use vta::compiler::builder::ProgramBuilder;
use vta::compiler::conv::{lower_conv, ConvBases, ConvParams};
use vta::compiler::tps::{self, ConvSpec};
use vta::config::presets;
use vta::isa::{BufferId, Insn, Opcode};
use vta::mem::Dram;
use vta::sim::Tsim;
use vta::trace::{first_divergence, trace_fsim, TraceMode};
use vta::util::rng::Pcg32;

fn small_conv_program(dram: &mut Dram, seed: u64) -> Vec<Insn> {
    let cfg = presets::tiny_config();
    let spec = ConvSpec {
        c_in: 8,
        c_out: 8,
        h: 6,
        w: 6,
        kh: 3,
        kw: 3,
        sh: 1,
        sw: 1,
        ph: 1,
        pw: 1,
    };
    let mut rng = Pcg32::seeded(seed);
    // Stage input + weights.
    let inp_bytes = 2 * 6 * 6 * cfg.inp_tile_bytes();
    let wgt_bytes = 2 * 2 * 9 * cfg.wgt_tile_bytes();
    let out_bytes = 2 * 6 * 6 * cfg.out_tile_bytes();
    let ri = dram.alloc(inp_bytes, cfg.inp_tile_bytes());
    let rw = dram.alloc(wgt_bytes, cfg.wgt_tile_bytes());
    let ro = dram.alloc(out_bytes, cfg.out_tile_bytes());
    dram.write_i8(ri, &rng.i8_vec(inp_bytes));
    dram.write_i8(rw, &rng.i8_vec(wgt_bytes));
    let tiling = tps::search(&spec, &cfg, true);
    let mut b = ProgramBuilder::new(&cfg);
    lower_conv(
        &mut b,
        &ConvParams { spec, shift: 4, relu: true },
        &tiling,
        ConvBases {
            inp: ri.tile_base(cfg.inp_tile_bytes()),
            wgt: rw.tile_base(cfg.wgt_tile_bytes()),
            out: ro.tile_base(cfg.out_tile_bytes()),
        },
    );
    b.finish("inject", dram).insns
}

#[test]
#[should_panic(expected = "deadlock")]
fn dropping_a_push_token_deadlocks_tsim() {
    // Remove the first push_next from a load instruction: the dependent
    // compute pops a token that never arrives. The simulator must report
    // deadlock (not hang, not silently compute).
    let cfg = presets::tiny_config();
    let mut dram = Dram::new(1 << 22);
    let mut insns = small_conv_program(&mut dram, 1);
    let victim = insns
        .iter()
        .position(|i| {
            matches!(i, Insn::Mem(m) if m.opcode == Opcode::Load && i.deps().push_next)
        })
        .expect("program should contain a load that signals compute");
    insns[victim].deps_mut().push_next = false;
    let mut sim = Tsim::new(&cfg);
    sim.run(&insns, &mut dram, "deadlock-injection");
}

#[test]
fn corrupted_instruction_diverges_and_is_localized() {
    // Flip one GEMM's loop extent: fsim traces of good vs bad programs
    // must diverge exactly at that instruction (paper: "pinpointed the
    // location in the trace where the behavior ... diverged").
    let cfg = presets::tiny_config();
    let mode = TraceMode::default();
    let mut d1 = Dram::new(1 << 22);
    let good = small_conv_program(&mut d1, 2);
    let mut d2 = Dram::new(1 << 22);
    let mut bad = small_conv_program(&mut d2, 2);
    let victim = bad
        .iter()
        .position(|i| matches!(i, Insn::Gemm(g) if !g.reset))
        .expect("program contains a GEMM");
    if let Insn::Gemm(g) = &mut bad[victim] {
        g.lp_in = g.lp_in.max(2) - 1; // drop one reduction iteration
    }
    let t_good = trace_fsim(&cfg, &good, &mut d1, &mode);
    let t_bad = trace_fsim(&cfg, &bad, &mut d2, &mode);
    let (at, buffer) = first_divergence(&t_good, &t_bad).expect("must diverge");
    assert_eq!(at, victim, "divergence localized at the corrupted instruction");
    assert_eq!(buffer, BufferId::Acc, "GEMM corruption shows in the accumulator");
}

#[test]
fn corrupted_weights_caught_by_golden_comparison() {
    // End-to-end: flip one staged weight byte; the CPU-reference check
    // must fail (this is what the CI equality-checking stage catches).
    use vta::compiler::graph::{Graph, Op};
    use vta::compiler::layout::Shape;
    use vta::runtime::{Session, SessionOptions};
    let cfg = presets::tiny_config();
    let mut rng = Pcg32::seeded(3);
    let weights = rng.i8_vec(8 * 8 * 9);
    let input = rng.i8_vec(8 * 6 * 6);
    let build = |w: Vec<i8>| {
        let mut g = Graph::new("wcheck", Shape::new(8, 6, 6));
        g.add(
            "conv",
            Op::Conv { c_out: 8, k: 3, stride: 1, pad: 1, shift: 4, relu: true, weights: w },
            vec![0],
        );
        g
    };
    let good = build(weights.clone());
    let mut corrupt = weights;
    corrupt[17] = corrupt[17].wrapping_add(1);
    let bad = build(corrupt);
    let expect = good.run_cpu(&input, 1);
    let mut s = Session::new(&cfg, SessionOptions::default()).unwrap();
    let got = s.run_graph(&bad, &input).unwrap();
    assert_ne!(got, expect, "corruption must be visible in the output");
}

#[test]
fn truncated_program_missing_finish_rejected() {
    let cfg = presets::tiny_config();
    let mut dram = Dram::new(1 << 22);
    let mut insns = small_conv_program(&mut dram, 4);
    insns.pop(); // drop FINISH
    let mut sim = Tsim::new(&cfg);
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        sim.run(&insns, &mut dram, "no-finish");
    }));
    assert!(result.is_err(), "missing FINISH must be rejected");
}
