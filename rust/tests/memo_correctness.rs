//! Memo / timing-only correctness invariants (the contract that makes
//! the ISSUE-2 fast paths sound):
//!
//! * timing-only simulation produces *exactly* the cycles, per-layer
//!   stats, and execution counters of a functional run;
//! * memoized runs (cold and warm, timing-only and functional) are
//!   bit-identical to unmemoized runs in all of the above;
//! * functional-mode memo hits preserve network outputs bit-exactly
//!   (hits replay the program through the shared exec core).

use std::sync::Arc;
use vta::compiler::graph::{Graph, Op};
use vta::compiler::layout::Shape;
use vta::config::presets;
use vta::engine::BackendKind;
use vta::exec::ExecCounters;
use vta::memo::LayerMemo;
use vta::runtime::{LayerStat, Session, SessionOptions};
use vta::util::prop::Prop;
use vta::util::rng::Pcg32;
use vta::workloads;
use vta::{prop_assert, prop_assert_eq};

/// Timing-only session options (the fast-path rung of the ladder).
fn timing(memo: Option<Arc<LayerMemo>>) -> SessionOptions {
    SessionOptions { backend: BackendKind::TsimTiming, memo, ..Default::default() }
}

/// Comparable projection of a `LayerStat` (the struct itself does not
/// implement `PartialEq`).
type StatKey = (String, &'static str, u64, usize, usize, u64, u64, u64, bool);

fn stat_key(s: &LayerStat) -> StatKey {
    (s.name.clone(), s.kind, s.cycles, s.insns, s.uops, s.macs, s.dram_rd, s.dram_wr, s.on_cpu)
}

type RunResult = (Vec<i8>, u64, ExecCounters, Vec<StatKey>);

fn run(
    graph: &Graph,
    input: &[i8],
    cfg: &vta::config::VtaConfig,
    opts: SessionOptions,
) -> RunResult {
    let mut s = Session::new(cfg, opts).unwrap();
    let out = s.run_graph(graph, input).unwrap();
    let stats = s.layer_stats.iter().map(stat_key).collect();
    (out, s.cycles(), s.exec_counters(), stats)
}

#[test]
fn micro_resnet_fast_paths_match_functional() {
    let cfg = presets::default_config();
    let g = workloads::micro_resnet(16, 3);
    let mut rng = Pcg32::seeded(11);
    let input = rng.i8_vec(cfg.batch * g.input_shape.elems());

    let base = run(&g, &input, &cfg, SessionOptions::default());
    let fast = run(&g, &input, &cfg, timing(None));
    assert_eq!(fast.1, base.1, "timing-only cycles must match functional exactly");
    assert_eq!(fast.2, base.2, "timing-only counters must match functional exactly");
    assert_eq!(fast.3, base.3, "timing-only per-layer stats must match functional exactly");

    let memo = Arc::new(LayerMemo::in_memory());
    let cold = run(
        &g,
        &input,
        &cfg,
        SessionOptions { memo: Some(memo.clone()), ..Default::default() },
    );
    assert!(
        memo.hits() > 0,
        "micro-resnet repeats layer shapes (residual blocks); expected in-network hits"
    );
    assert_eq!(cold.0, base.0, "functional memo hits must preserve outputs bit-exactly");
    assert_eq!((cold.1, cold.2, &cold.3), (base.1, base.2, &base.3));

    let warm_timing = run(&g, &input, &cfg, timing(Some(memo.clone())));
    assert_eq!((warm_timing.1, warm_timing.2, &warm_timing.3), (base.1, base.2, &base.3));
}

#[test]
fn prop_memoized_and_plain_runs_bit_identical() {
    Prop::new("memo-bit-identical").cases(10).run(|g| {
        let cfg = presets::tiny_config();
        let block = cfg.block_in;
        let c = block * g.usize(1, 2);
        let hw = g.usize(6, 10);
        let relu = g.bool();
        let shift = g.i64(0, 5) as u32;
        let mut graph = Graph::new("prop-memo", Shape::new(c, hw, hw));
        let c1 = graph.add(
            "conv1",
            Op::Conv {
                c_out: c,
                k: 3,
                stride: 1,
                pad: 1,
                shift,
                relu,
                weights: g.vec_i8(c * c * 9),
            },
            vec![0],
        );
        // Same shape, different weights: an in-network memo hit whose
        // functional replay must still use *these* weights.
        let c2 = graph.add(
            "conv2",
            Op::Conv {
                c_out: c,
                k: 3,
                stride: 1,
                pad: 1,
                shift,
                relu,
                weights: g.vec_i8(c * c * 9),
            },
            vec![c1],
        );
        let add = graph.add("add", Op::Add { relu: true }, vec![c2, c1]);
        let pool = graph.add("pool", Op::MaxPool { k: 2, stride: 2, pad: 0 }, vec![add]);
        let gap = graph.add("gap", Op::GlobalAvgPool, vec![pool]);
        graph.add(
            "fc",
            Op::Dense { units: 8, shift: 2, relu: false, weights: g.vec_i8(8 * c) },
            vec![gap],
        );
        let input = g.vec_i8(cfg.batch * graph.input_shape.elems());

        let base = run(&graph, &input, &cfg, SessionOptions::default());
        let memo = Arc::new(LayerMemo::in_memory());
        let cold = run(
            &graph,
            &input,
            &cfg,
            SessionOptions { memo: Some(memo.clone()), ..Default::default() },
        );
        let warm = run(
            &graph,
            &input,
            &cfg,
            SessionOptions { memo: Some(memo.clone()), ..Default::default() },
        );
        let timing_memo = run(&graph, &input, &cfg, timing(Some(memo.clone())));
        let timing_plain = run(&graph, &input, &cfg, timing(None));

        prop_assert!(memo.hits() > 0, "conv2 repeats conv1's shape; expected a hit");
        prop_assert_eq!(&cold.0, &base.0);
        prop_assert_eq!(&warm.0, &base.0);
        for r in [&cold, &warm, &timing_memo, &timing_plain] {
            prop_assert_eq!(r.1, base.1);
            prop_assert_eq!(r.2, base.2);
            prop_assert_eq!(&r.3, &base.3);
        }
        Ok(())
    });
}
