//! Golden verification: the rust-simulated accelerator vs the
//! AOT-compiled JAX/Pallas model executed through PJRT. Bit-exact
//! agreement across the whole three-layer stack is the release gate.
//!
//! Tests skip (with a notice) when `artifacts/` has not been built —
//! run `make artifacts` first.

use vta::compiler::graph::{Graph, Op};
use vta::compiler::layout::Shape;
use vta::config::presets;
use vta::engine::BackendKind;
use vta::runtime::pjrt::Golden;
use vta::runtime::{Session, SessionOptions};
use vta::util::rng::Pcg32;

fn golden_or_skip(names: &[&str]) -> Option<Golden> {
    let g = Golden::with_default_dir().expect("PJRT client");
    for n in names {
        if !g.has_artifact(n) {
            eprintln!("SKIP: artifact '{n}' missing; run `make artifacts`");
            return None;
        }
    }
    Some(g)
}

#[test]
fn gemm_kernel_matches_exec_core() {
    let Some(mut golden) = golden_or_skip(&["gemm"]) else { return };
    let mut rng = Pcg32::seeded(21);
    let x = rng.i8_vec_full(64 * 64);
    let w = rng.i8_vec_full(64 * 16);
    let got = golden
        .run_i8_to_i32("gemm", &x, &[64, 64], &w, &[64, 16])
        .expect("golden gemm run");
    // Reference: plain int32 matmul (same as the exec core's tile op).
    let mut expect = vec![0i32; 64 * 16];
    for i in 0..64 {
        for j in 0..16 {
            let mut acc = 0i32;
            for kk in 0..64 {
                acc += x[i * 64 + kk] as i32 * w[kk * 16 + j] as i32;
            }
            expect[i * 16 + j] = acc;
        }
    }
    assert_eq!(got, expect, "Pallas GEMM artifact != int32 reference");
}

fn run_conv_on_stack(
    cfg: &vta::config::VtaConfig,
    backend: BackendKind,
    c_in: usize,
    c_out: usize,
    hw: usize,
    stride: usize,
    shift: u32,
    relu: bool,
    weights: &[i8],
    input: &[i8],
) -> Vec<i8> {
    let mut g = Graph::new("golden-conv", Shape::new(c_in, hw, hw));
    g.add(
        "conv",
        Op::Conv { c_out, k: 3, stride, pad: 1, shift, relu, weights: weights.to_vec() },
        vec![0],
    );
    let mut s = Session::new(cfg, SessionOptions { backend, ..Default::default() }).unwrap();
    s.run_graph(&g, input).unwrap()
}

#[test]
fn conv_quickstart_stack_vs_golden() {
    // x: [1,16,14,14], w: [16,16,3,3], stride 1 pad 1 shift 5 relu —
    // must agree bit-for-bit between tsim, fsim and the PJRT golden.
    let Some(mut golden) = golden_or_skip(&["conv_quickstart"]) else { return };
    let cfg = presets::default_config();
    let mut rng = Pcg32::seeded(33);
    let x = rng.i8_vec(16 * 14 * 14);
    let w = rng.i8_vec(16 * 16 * 9);
    let want = golden
        .run_i8("conv_quickstart", &x, &[1, 16, 14, 14], &w, &[16, 16, 3, 3])
        .expect("golden conv run");
    for backend in [BackendKind::Fsim, BackendKind::Tsim] {
        let got = run_conv_on_stack(&cfg, backend, 16, 16, 14, 1, 5, true, &w, &x);
        assert_eq!(got, want, "{backend:?} disagrees with PJRT golden");
    }
}

#[test]
fn conv_stride2_stack_vs_golden() {
    // x: [1,32,12,12], w: [16,32,3,3], stride 2 pad 1 shift 6 no relu.
    let Some(mut golden) = golden_or_skip(&["conv_stride2"]) else { return };
    let cfg = presets::default_config();
    let mut rng = Pcg32::seeded(34);
    let x = rng.i8_vec(32 * 12 * 12);
    let w = rng.i8_vec(16 * 32 * 9);
    let want = golden
        .run_i8("conv_stride2", &x, &[1, 32, 12, 12], &w, &[16, 32, 3, 3])
        .expect("golden conv run");
    let got = run_conv_on_stack(&cfg, BackendKind::Tsim, 32, 16, 12, 2, 6, false, &w, &x);
    assert_eq!(got, want, "tsim disagrees with PJRT golden (stride 2)");
}

#[test]
fn dense_stack_vs_golden() {
    // x: [4,64] (batch 4!), w: [32,64], shift 4. Uses a batch=4 config.
    let Some(mut golden) = golden_or_skip(&["dense"]) else { return };
    let mut cfg = presets::default_config();
    cfg.batch = 4;
    let mut rng = Pcg32::seeded(35);
    let x = rng.i8_vec(4 * 64);
    let w = rng.i8_vec(32 * 64);
    let want =
        golden.run_i8("dense", &x, &[4, 64], &w, &[32, 64]).expect("golden dense run");
    let mut g = Graph::new("golden-dense", Shape::new(64, 1, 1));
    g.add(
        "fc",
        Op::Dense { units: 32, shift: 4, relu: false, weights: w.clone() },
        vec![0],
    );
    let mut s = Session::new(&cfg, SessionOptions::default()).unwrap();
    let got = s.run_graph(&g, &x).unwrap();
    assert_eq!(got, want, "tsim dense disagrees with PJRT golden");
}
