//! Cross-subsystem artifact-store reuse (the ISSUE-9 acceptance
//! invariants): one content-addressed store spans the sweep engine, the
//! Fig 13 repro driver, serve warmup, and model calibration.
//!
//! * a reduced-grid sweep populates the store, after which `repro
//!   fig13` over the same grid simulates **nothing** and a `vta serve`
//!   pool prices its warmup from the sweep's `PointMeasurement`s;
//! * the manifest's last-run counters report >= 90% reuse on the warm
//!   re-run (the `vta cache stats` acceptance gate);
//! * store-on and store-off sweeps are byte-identical, point for point;
//! * calibration tables are first-class artifacts a fresh process
//!   reuses byte-for-byte;
//! * the op-graph planner derives the expected minimal path from what
//!   the store actually holds after a sweep.

use std::collections::BTreeSet;
use std::path::PathBuf;
use std::sync::Arc;

use vta::config::presets;
use vta::engine::BackendKind;
use vta::model::calib;
use vta::repro;
use vta::serve::{ServeOptions, SessionPool};
use vta::store::{plan, ArtifactKind, ArtifactStore, OpKind};
use vta::sweep::{self, GridSpec, SweepOptions, SweepSpec, WorkloadSpec};
use vta::workloads;

/// A fresh per-test store directory (removed on entry so a crashed
/// earlier run can never leak artifacts into this one).
fn temp_store(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("vta_store_it_{}_{name}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// The fast 8-point micro grid (mirrors `sweep_engine.rs`).
fn micro_spec() -> SweepSpec {
    let mut configs = Vec::new();
    for axi in [8usize, 16] {
        for scale in [1usize, 2] {
            let mut cfg = presets::tiny_config();
            cfg.name = format!("tiny-s{scale}-m{axi}");
            cfg.axi_bytes = axi;
            cfg.inp_depth *= scale;
            cfg.wgt_depth *= scale;
            cfg.acc_depth *= scale;
            configs.push(cfg);
        }
    }
    SweepSpec {
        configs,
        workloads: vec![WorkloadSpec::Micro { block: 4 }],
        seeds: vec![7, 8],
        graph_seed: 42,
    }
}

/// Tentpole acceptance: sweep -> fig13 -> serve share one measurement
/// pool. The quick Fig 13 grid is swept cold into an on-disk store;
/// a subsequent `repro fig13` against a *fresh handle* on the same
/// directory re-simulates nothing, the manifest reports >= 90% reuse,
/// and a serve pool over one of the grid's (config, workload) points
/// warms up from the stored measurement without evaluating.
#[test]
fn store_spans_sweep_fig13_and_serve_warmup() {
    let dir = temp_store("fig13_serve");
    let spec = GridSpec::fig13(true).to_sweep_spec();
    let n = spec.jobs().len();

    // Cold sweep: every grid point simulates and lands in the store.
    let store = Arc::new(ArtifactStore::open(&dir).unwrap());
    let cold = sweep::run(
        &spec,
        &SweepOptions {
            jobs: 2,
            memo: true,
            backend: BackendKind::TsimTiming,
            store: Some(store.clone()),
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(cold.simulated, n);
    assert_eq!(cold.cached, 0);
    assert_eq!(store.len(ArtifactKind::PointMeasurement), n);
    assert_eq!(store.len(ArtifactKind::Graph), 1, "one workload, one graph artifact");
    assert!(store.len(ArtifactKind::Program) > 0, "the memo persists lowered layers");

    // The planner sees what the sweep left behind: a measurement is
    // already materialized (empty path); a serve report is blocked on
    // the trace source state, and once a trace exists it needs the
    // serve op — and only that op.
    let mut have: BTreeSet<ArtifactKind> = store.have();
    assert!(have.contains(&ArtifactKind::PointMeasurement));
    assert_eq!(plan(ArtifactKind::PointMeasurement, &have), Some(vec![]));
    assert_eq!(plan(ArtifactKind::ServeReport, &have), None, "no op fabricates a trace");
    have.insert(ArtifactKind::Trace);
    assert_eq!(plan(ArtifactKind::ServeReport, &have), Some(vec![OpKind::Serve]));

    // `repro fig13` from a fresh handle: zero simulations, same rows.
    drop(store);
    let store = Arc::new(ArtifactStore::open(&dir).unwrap());
    let rows = repro::fig13_with_store(true, 2, Some(store.clone()));
    assert_eq!(rows.len(), n);
    assert_eq!(store.misses(), 0, "fig13 after the sweep must not simulate");
    assert_eq!(store.hits(), n as u64, "every figure row is a store reuse");
    for (row, r) in rows.iter().zip(&cold.results) {
        assert_eq!(row.cycles, r.cycles, "figure rows must carry the sweep's cycles");
        assert_eq!(row.scaled_area, r.scaled_area);
    }

    // The acceptance gate `vta cache stats` reads: the warm run's
    // persisted reuse ratio is >= 0.9 (here: all n points reused).
    let reuse = ArtifactStore::open(&dir)
        .unwrap()
        .stats()
        .last_run_reuse()
        .expect("the warm run synced its reuse counters to the manifest");
    assert!(reuse >= 0.9, "warm re-run must reuse >= 90% of artifacts, got {reuse}");

    // Serve warmup consumes the sweep's PointMeasurement for the same
    // (config, workload, graph_seed, residency) — across subsystems.
    let store = Arc::new(ArtifactStore::open(&dir).unwrap());
    let opts = ServeOptions::builder()
        .cfg(spec.configs[0].clone())
        .backend(BackendKind::TsimTiming)
        .workloads(vec![WorkloadSpec::Resnet { depth: 18, hw: 56 }])
        .graph_seed(spec.graph_seed)
        .store(Some(store.clone()))
        .build()
        .unwrap();
    let pool = SessionPool::build(&opts).unwrap();
    let entry = pool.get("resnet18@56").expect("the pool serves the grid workload");
    assert!(entry.warmed_from_store, "warmup must reuse the sweep's measurement");
    assert_eq!(
        entry.cycles_per_request, cold.results[0].cycles,
        "the stored measurement prices the serve request"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Satellite acceptance: routing a sweep through the store changes no
/// output byte. Results and frontier of a store-backed run equal the
/// store-free baseline down to their serialized JSON, and a warm
/// re-run from the same directory reproduces them with zero
/// simulations.
#[test]
fn store_backed_sweep_is_byte_identical_to_store_free() {
    let spec = micro_spec();
    let n = spec.jobs().len();
    let ser = |o: &sweep::SweepOutcome| -> Vec<String> {
        o.results.iter().map(|r| r.to_json().to_string_compact()).collect()
    };

    let baseline = sweep::run(&spec, &SweepOptions { jobs: 2, ..Default::default() }).unwrap();

    let dir = temp_store("byte_identical");
    let store = Arc::new(ArtifactStore::open(&dir).unwrap());
    let stored = sweep::run(
        &spec,
        &SweepOptions { jobs: 2, store: Some(store.clone()), ..Default::default() },
    )
    .unwrap();
    assert_eq!(baseline.results, stored.results);
    assert_eq!(baseline.front.points(), stored.front.points());
    assert_eq!(ser(&baseline), ser(&stored), "store-on output must be byte-identical");

    let warm = sweep::run(
        &spec,
        &SweepOptions { jobs: 4, store: Some(store.clone()), ..Default::default() },
    )
    .unwrap();
    assert_eq!(warm.simulated, 0, "the store always resumes");
    assert_eq!(warm.cached, n);
    assert_eq!(warm.skipped_stale, 0);
    assert_eq!(ser(&warm), ser(&baseline), "warm bytes must equal the cold run's");
    assert_eq!(warm.front.points(), baseline.front.points());
    std::fs::remove_dir_all(&dir).ok();
}

/// Calibration ρ tables are first-class artifacts: computed once,
/// reused byte-for-byte by a fresh process, and identical to a
/// store-free calibration.
#[test]
fn calibration_is_a_first_class_reusable_artifact() {
    let dir = temp_store("calib");
    let cfg = presets::tiny_config();
    let graph = workloads::micro_resnet(4, 42);

    let store = ArtifactStore::open(&dir).unwrap();
    let first = calib::calibrate_graph_with_store(&cfg, &graph, &store).unwrap();
    assert_eq!(store.len(ArtifactKind::Calibration), 1);

    let store = ArtifactStore::open(&dir).unwrap();
    let second = calib::calibrate_graph_with_store(&cfg, &graph, &store).unwrap();
    assert_eq!(store.hits(), 1, "the second calibration is a store lookup");
    assert_eq!(store.len(ArtifactKind::Calibration), 1, "no duplicate artifact");
    assert_eq!(
        first.to_json().to_string_compact(),
        second.to_json().to_string_compact(),
        "a reused calibration table must be byte-identical"
    );
    let plain = calib::calibrate_graph(&cfg, &graph);
    assert_eq!(
        plain.to_json().to_string_compact(),
        first.to_json().to_string_compact(),
        "going through the store must not change the table"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// `verify` and `gc` hold their contracts on a store a real sweep
/// produced: verify passes, a dry-run gc changes nothing, and a real
/// gc compacts duplicates away while every artifact survives.
#[test]
fn verify_and_gc_on_a_real_sweep_store() {
    let dir = temp_store("verify_gc");
    let spec = micro_spec();
    let store = Arc::new(ArtifactStore::open(&dir).unwrap());
    sweep::run(
        &spec,
        &SweepOptions { jobs: 2, store: Some(store.clone()), ..Default::default() },
    )
    .unwrap();
    let n = store.len(ArtifactKind::PointMeasurement);
    drop(store);

    let store = ArtifactStore::open(&dir).unwrap();
    assert!(store.verify().unwrap().ok(), "a freshly written store must verify clean");
    let dry = store.gc(true).unwrap();
    assert!(dry.dry_run);
    assert_eq!(dry.dropped_stale + dry.dropped_corrupt + dry.dropped_duplicate, 0);
    let real = store.gc(false).unwrap();
    assert_eq!(real.kept, dry.kept, "a clean store compacts to itself");

    let store = ArtifactStore::open(&dir).unwrap();
    assert_eq!(store.len(ArtifactKind::PointMeasurement), n, "gc must keep every artifact");
    assert!(store.verify().unwrap().ok(), "the compacted store verifies clean");
    std::fs::remove_dir_all(&dir).ok();
}
