//! Property-based tests over the stack's core invariants (mini-proptest
//! in `vta::util::prop`): ISA encode/decode inversion across random
//! configurations, dependency-token safety of generated programs, TPS
//! feasibility soundness, layout pack/unpack inversion, and fsim==tsim
//! state equivalence on randomized conv layers.

use vta::compiler::cpu_ref;
use vta::compiler::graph::{Graph, Op};
use vta::compiler::layout::{self, Shape};
use vta::compiler::tps::{self, ConvSpec};
use vta::config::{presets, VtaConfig};
use vta::engine::BackendKind;
use vta::exec::ExecCounters;
use vta::isa::{AluInsn, AluOp, BufferId, DepFlags, GemmInsn, Insn, MemInsn, Opcode, Uop};
use vta::runtime::{Session, SessionOptions};
use vta::util::json::Json;
use vta::util::prop::{Gen, Prop};
use vta::{prop_assert, prop_assert_eq};

/// A random-but-valid configuration.
fn gen_config(g: &mut Gen) -> VtaConfig {
    let block = g.pow2(2, 6); // 4..64
    VtaConfig {
        name: "prop".into(),
        batch: g.pow2(0, 1),
        block_in: block,
        block_out: block,
        uop_depth: g.pow2(9, 13),
        inp_depth: g.pow2(7, 11),
        wgt_depth: g.pow2(7, 10),
        acc_depth: g.pow2(7, 11),
        axi_bytes: g.pow2(3, 6),
        dram_latency: g.i64(1, 64) as u64,
        vme_inflight: g.i64(1, 16) as usize,
        gemm_pipelined: g.bool(),
        alu_pipelined: g.bool(),
        cmd_queue_depth: 256,
        dep_queue_depth: 64,
        precision: if g.bool() {
            vta::config::Precision::Narrow
        } else {
            vta::config::Precision::Wide
        },
    }
}

#[test]
fn prop_isa_roundtrip_random_configs() {
    Prop::new("isa-roundtrip").cases(200).run(|g| {
        let cfg = gen_config(g);
        if cfg.validate().is_err() {
            return Ok(()); // skip invalid corners
        }
        let l = cfg.isa_layout();
        let insn = match g.i64(0, 3) {
            0 => Insn::Mem(MemInsn {
                opcode: if g.bool() { Opcode::Load } else { Opcode::Store },
                deps: DepFlags::from_bits(g.i64(0, 15) as u64),
                buffer: *g.choose(&BufferId::ALL),
                sram_base: g.i64(0, (1 << l.sram_bits) - 1) as u32,
                dram_base: g.i64(0, (1i64 << 31) - 1) as u32,
                y_size: g.i64(0, (1 << l.mem_size_bits) - 1) as u32,
                x_size: g.i64(0, (1 << l.mem_size_bits) - 1) as u32,
                x_stride: g.i64(0, (1 << l.mem_size_bits) - 1) as u32,
                y_pad0: g.i64(0, 15) as u32,
                y_pad1: g.i64(0, 15) as u32,
                x_pad0: g.i64(0, 15) as u32,
                x_pad1: g.i64(0, 15) as u32,
                pad_value: g.i8(),
            }),
            1 => Insn::Gemm(GemmInsn {
                deps: DepFlags::from_bits(g.i64(0, 15) as u64),
                reset: g.bool(),
                uop_bgn: g.i64(0, (1 << l.uop_idx_bits) - 1) as u32,
                uop_end: g.i64(0, (1 << (l.uop_idx_bits + 1)) - 1) as u32,
                lp_out: g.i64(0, (1 << l.loop_bits) - 1) as u32,
                lp_in: g.i64(0, (1 << l.loop_bits) - 1) as u32,
                acc_f0: g.i64(0, (1 << l.acc_idx_bits) - 1) as u32,
                acc_f1: g.i64(0, (1 << l.acc_idx_bits) - 1) as u32,
                inp_f0: g.i64(0, (1 << l.inp_idx_bits) - 1) as u32,
                inp_f1: g.i64(0, (1 << l.inp_idx_bits) - 1) as u32,
                wgt_f0: g.i64(0, (1 << l.wgt_idx_bits) - 1) as u32,
                wgt_f1: g.i64(0, (1 << l.wgt_idx_bits) - 1) as u32,
            }),
            2 => Insn::Alu(AluInsn {
                deps: DepFlags::from_bits(g.i64(0, 15) as u64),
                reset: g.bool(),
                op: *g.choose(&[
                    AluOp::Min,
                    AluOp::Max,
                    AluOp::Add,
                    AluOp::Shr,
                    AluOp::Mul,
                    AluOp::Clip,
                    AluOp::Mov,
                ]),
                uop_bgn: g.i64(0, (1 << l.uop_idx_bits) - 1) as u32,
                uop_end: g.i64(0, (1 << (l.uop_idx_bits + 1)) - 1) as u32,
                lp_out: g.i64(0, (1 << l.loop_bits) - 1) as u32,
                lp_in: g.i64(0, (1 << l.loop_bits) - 1) as u32,
                dst_f0: g.i64(0, (1 << l.acc_idx_bits) - 1) as u32,
                dst_f1: g.i64(0, (1 << l.acc_idx_bits) - 1) as u32,
                src_f0: g.i64(0, (1 << l.acc_idx_bits) - 1) as u32,
                src_f1: g.i64(0, (1 << l.acc_idx_bits) - 1) as u32,
                use_imm: g.bool(),
                imm: g.i64(-(1 << (l.imm_bits - 1)), (1 << (l.imm_bits - 1)) - 1) as i32,
            }),
            _ => Insn::Finish(DepFlags::from_bits(g.i64(0, 15) as u64)),
        };
        let back = Insn::decode(insn.encode(&l), &l)
            .map_err(|e| format!("decode failed: {e}"))?;
        prop_assert_eq!(back, insn);
        Ok(())
    });
}

#[test]
fn prop_uop_roundtrip() {
    Prop::new("uop-roundtrip").cases(200).run(|g| {
        let cfg = gen_config(g);
        if cfg.validate().is_err() {
            return Ok(());
        }
        let l = cfg.isa_layout();
        let u = Uop::gemm(
            g.i64(0, cfg.acc_depth as i64 - 1) as u32,
            g.i64(0, cfg.inp_depth as i64 - 1) as u32,
            g.i64(0, cfg.wgt_depth as i64 - 1) as u32,
        );
        prop_assert_eq!(Uop::decode(u.encode(&l), &l), u);
        Ok(())
    });
}

#[test]
fn prop_activation_pack_unpack_inverse() {
    Prop::new("layout-inverse").cases(100).run(|g| {
        let batch = g.usize(1, 2);
        let shape = Shape::new(g.usize(1, 9), g.usize(1, 6), g.usize(1, 6));
        let block = g.pow2(1, 3);
        let data = g.vec_i8(batch * shape.elems());
        let tiled = layout::pack_activation(&data, batch, shape, block);
        prop_assert_eq!(layout::unpack_activation(&tiled, batch, shape, block), data);
        Ok(())
    });
}

#[test]
fn prop_tps_search_always_feasible_and_no_worse_than_fallback() {
    Prop::new("tps-feasible").cases(40).run(|g| {
        let cfg = match g.i64(0, 2) {
            0 => presets::default_config(),
            1 => presets::scaled_config(1, 32, 32, 2, 16),
            _ => presets::tiny_config(),
        };
        let block = cfg.block_in;
        let spec = ConvSpec {
            c_in: block * g.usize(1, 4),
            c_out: block * g.usize(1, 4),
            h: g.usize(4, 28),
            w: g.usize(4, 28),
            kh: *g.choose(&[1, 3]),
            kw: 0,
            sh: g.usize(1, 2),
            sw: 0,
            ph: 0,
            pw: 0,
        };
        let spec = ConvSpec {
            kw: spec.kh,
            sw: spec.sh,
            ph: spec.kh / 2,
            pw: spec.kh / 2,
            ..spec
        };
        if spec.h < spec.kh || spec.w < spec.kw {
            return Ok(());
        }
        let best = tps::search(&spec, &cfg, true);
        prop_assert!(best.feasible(&spec, &cfg), "search returned infeasible tiling");
        let fb = tps::fallback(&spec, &cfg);
        if fb.feasible(&spec, &cfg) {
            prop_assert!(
                best.dram_bytes(&spec, &cfg) <= fb.dram_bytes(&spec, &cfg),
                "TPS worse than fallback"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_random_conv_fsim_tsim_cpu_agree() {
    // The heavyweight invariant: for random small convs, the compiled
    // program computes identically on fsim and tsim and matches the CPU
    // reference (catching scheduling/token bugs via real divergence).
    Prop::new("conv-equivalence").cases(12).run(|g| {
        let cfg = presets::tiny_config();
        let block = cfg.block_in;
        let c_in = block * g.usize(1, 2);
        let c_out = block * g.usize(1, 2);
        let hw = g.usize(4, 10);
        let k = *g.choose(&[1usize, 3]);
        let stride = g.usize(1, 2);
        if hw < k {
            return Ok(());
        }
        let pad = k / 2;
        let mut graph = Graph::new("prop-conv", Shape::new(c_in, hw, hw));
        graph.add(
            "conv",
            Op::Conv {
                c_out,
                k,
                stride,
                pad,
                shift: g.i64(0, 6) as u32,
                relu: g.bool(),
                weights: g.vec_i8(c_out * c_in * k * k),
            },
            vec![0],
        );
        let input = g.vec_i8(cfg.batch * graph.input_shape.elems());
        let expect = graph.run_cpu(&input, cfg.batch);
        let reuse = g.bool();
        let tps_on = g.bool();
        for backend in [BackendKind::Fsim, BackendKind::Tsim] {
            let mut s = Session::new(
                &cfg,
                SessionOptions { backend, dbuf_reuse: reuse, tps: tps_on, ..Default::default() },
            )
            .map_err(|e| format!("session: {e}"))?;
            let got = s.run_graph(&graph, &input).map_err(|e| format!("run: {e}"))?;
            prop_assert!(
                got == expect,
                "{backend:?} mismatch (c_in={c_in} c_out={c_out} hw={hw} k={k} s={stride} reuse={reuse} tps={tps_on})"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_dependency_tokens_never_deadlock_random_pools() {
    // Random pooling layers exercise the compute-store token protocol.
    Prop::new("pool-no-deadlock").cases(12).run(|g| {
        let cfg = presets::tiny_config();
        let c = cfg.block_in * g.usize(1, 2);
        let hw = g.usize(4, 12);
        let k = g.usize(2, 3.min(hw));
        let stride = g.usize(1, 2);
        let mut graph = Graph::new("prop-pool", Shape::new(c, hw, hw));
        graph.add("pool", Op::MaxPool { k, stride, pad: k / 2 }, vec![0]);
        let input = g.vec_i8(cfg.batch * graph.input_shape.elems());
        let expect = graph.run_cpu(&input, cfg.batch);
        let mut s = Session::new(&cfg, SessionOptions::default())
            .map_err(|e| format!("session: {e}"))?;
        let got = s.run_graph(&graph, &input).map_err(|e| format!("run: {e}"))?;
        prop_assert!(got == expect, "pool mismatch c={c} hw={hw} k={k} s={stride}");
        Ok(())
    });
}

#[test]
fn prop_exec_counters_json_roundtrip_is_lossless() {
    // The counter record is the layer-memo spill's payload: the
    // roundtrip must be the identity, and any record carrying more or
    // less than the exact field set must be rejected — unknown fields
    // silently dropped on load would desynchronize memo-spliced
    // sessions from simulated ones.
    Prop::new("exec-counters-lossless").cases(200).run(|g| {
        let mut draw = |hi: i64| g.i64(0, hi) as u64;
        let c = ExecCounters {
            insn_count: draw(1 << 40),
            gemm_ops: draw(1 << 40),
            macs: draw(1 << 50),
            alu_ops: draw(1 << 40),
            alu_elems: draw(1 << 45),
            load_bytes_inp: draw(1 << 45),
            load_bytes_wgt: draw(1 << 45),
            load_bytes_acc: draw(1 << 45),
            load_bytes_uop: draw(1 << 40),
            store_bytes: draw(1 << 45),
            pad_tiles: draw(1 << 30),
            resident_tile_hits: draw(1 << 30),
            dma_bytes_elided: draw(1 << 45),
        };
        let j = c.to_json();
        prop_assert_eq!(ExecCounters::from_json(&j), Some(c));

        // Adding any unknown field must reject the record outright.
        let mut extra = j.clone();
        if let Json::Object(map) = &mut extra {
            map.insert("mystery_counter".into(), Json::Int(1));
        }
        prop_assert_eq!(ExecCounters::from_json(&extra), None);

        // Dropping any single known field must reject it too.
        let victim = *g.choose(&ExecCounters::JSON_FIELDS);
        let mut missing = j;
        if let Json::Object(map) = &mut missing {
            map.remove(victim);
        }
        prop_assert_eq!(ExecCounters::from_json(&missing), None);
        Ok(())
    });
}

#[test]
fn prop_requant_saturates_to_i8() {
    // The quantized output range is the symmetric clip [-127, 127]:
    // -128 is never produced (the ALU CLIP is symmetric), ReLU floors at
    // zero, and saturating inputs pin exactly to the rails.
    Prop::new("requant-saturation").cases(500).run(|g| {
        let acc = g.i64(-(1 << 30), 1 << 30) as i32;
        let shift = g.i64(0, 16) as u32;
        let relu = g.bool();
        let v = cpu_ref::requant(acc, shift, relu);
        prop_assert!((-127..=127).contains(&v), "requant({acc}, {shift}) = {v} out of range");
        if relu {
            prop_assert!(v >= 0, "relu requant went negative: {v}");
        }
        prop_assert_eq!(cpu_ref::requant(i32::MAX / 2, shift, relu), 127);
        prop_assert_eq!(cpu_ref::requant(i32::MIN / 2, shift, false), -127);
        Ok(())
    });
}

#[test]
fn prop_requant_shr_rounds_half_up() {
    // Round-half-up means the de-shifted result sits within half an ulp
    // of the accumulator, with ties resolved toward +inf: the residual
    // `v*2^s - acc` lies in (-2^(s-1), 2^(s-1)].
    Prop::new("requant-rounding").cases(500).run(|g| {
        let shift = g.i64(1, 16) as u32;
        // Stay inside the un-clamped region so the clip doesn't mask
        // the rounding behaviour.
        let bound = 126i64 << shift;
        let acc = g.i64(-bound, bound) as i32;
        let v = cpu_ref::requant(acc, shift, false) as i64;
        let half = 1i64 << (shift - 1);
        let d = (v << shift) - acc as i64;
        prop_assert!(
            -half < d && d <= half,
            "requant({acc}, {shift}) = {v}: residual {d} outside (-{half}, {half}]"
        );
        Ok(())
    });
}

#[test]
fn prop_softmax_approx_is_monotone() {
    // Per reduced column: larger inputs never map to smaller outputs,
    // the column max always gets the full 127, and the range is
    // [0, 127] (a probability-like payload in Q7).
    Prop::new("softmax-monotone").cases(200).run(|g| {
        let (c, h, w) = (g.usize(1, 3), g.usize(2, 12), g.usize(1, 3));
        let shift = g.i64(0, 4) as u32;
        let inp = g.vec_i8(c * h * w);
        let out = cpu_ref::softmax_approx(&inp, 1, c, h, w, shift);
        for bc in 0..c {
            for x in 0..w {
                let col = |v: &[i8], y: usize| v[(bc * h + y) * w + x];
                let m = (0..h).map(|y| col(&inp, y)).max().unwrap();
                for y in 0..h {
                    prop_assert!((0..=127).contains(&col(&out, y)), "range violation");
                    if col(&inp, y) == m {
                        prop_assert_eq!(col(&out, y), 127);
                    }
                    for y2 in 0..h {
                        if col(&inp, y) >= col(&inp, y2) {
                            prop_assert!(
                                col(&out, y) >= col(&out, y2),
                                "monotonicity broken at shift={shift}: \
                                 in {} >= {} but out {} < {}",
                                col(&inp, y),
                                col(&inp, y2),
                                col(&out, y),
                                col(&out, y2)
                            );
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_layernorm_approx_is_shift_invariant() {
    // Adding a constant to every channel shifts the mean by exactly the
    // same constant (c is a power of two, so `c*delta` is exact under
    // the round-half-up shift by log2 c), leaving the centred output
    // bit-identical — the defining property of mean subtraction.
    Prop::new("layernorm-shift-invariant").cases(200).run(|g| {
        let c = g.pow2(0, 4); // 1..16 channels
        let hw = g.usize(1, 6);
        // Keep |x| <= 50 and |delta| <= 40 so neither the shifted
        // inputs nor the shifted mean can reach the ±127 clip.
        let inp: Vec<i8> = (0..c * hw).map(|_| g.i64(-50, 50) as i8).collect();
        let delta = g.i64(-40, 40) as i8;
        let shifted: Vec<i8> = inp.iter().map(|&v| v + delta).collect();
        let base = cpu_ref::layernorm_approx(&inp, 1, c, hw, 1);
        let moved = cpu_ref::layernorm_approx(&shifted, 1, c, hw, 1);
        prop_assert_eq!(base, moved);
        Ok(())
    });
}
