//! Public-API snapshot: the sorted `pub` items of the
//! `engine` / `runtime` / `serve` / `sweep` surface, pinned in a golden
//! file so a future PR cannot silently break the evaluation or serving
//! API (CI fails and shows the diff instead).
//!
//! The extraction is deliberately simple and deterministic — the first
//! line of every `pub `-prefixed item (trimmed, with a trailing `{`
//! stripped), prefixed by its file — rather than a full parser: the
//! goal is a tripwire for surface changes, not a semantic model.
//!
//! To accept an intentional API change, regenerate the golden file:
//!
//!     VTA_UPDATE_API=1 cargo test --test public_api
//!
//! and commit the updated `rust/tests/golden/public_api.txt` together
//! with a short rationale in the PR description.

use std::path::Path;

const MODULES: [&str; 4] =
    ["rust/src/engine", "rust/src/runtime", "rust/src/serve", "rust/src/sweep"];
const GOLDEN: &str = "rust/tests/golden/public_api.txt";

fn snapshot(root: &Path) -> String {
    let mut entries: Vec<String> = Vec::new();
    for dir in MODULES {
        let mut files: Vec<_> = std::fs::read_dir(root.join(dir))
            .expect("API module directory exists")
            .map(|e| e.expect("readable dir entry").path())
            .filter(|p| p.extension().is_some_and(|x| x == "rs"))
            .collect();
        files.sort();
        for file in files {
            let rel =
                format!("{dir}/{}", file.file_name().unwrap().to_string_lossy());
            let text = std::fs::read_to_string(&file).expect("readable source file");
            for line in text.lines() {
                let trimmed = line.trim();
                if !trimmed.starts_with("pub ") {
                    continue;
                }
                let mut sig = trimmed.to_string();
                if let Some(stripped) = sig.strip_suffix('{') {
                    sig = stripped.trim_end().to_string();
                }
                entries.push(format!("{rel}: {sig}"));
            }
        }
    }
    entries.sort();
    let mut out = entries.join("\n");
    out.push('\n');
    out
}

#[test]
fn public_api_matches_golden_snapshot() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let current = snapshot(root);
    let golden_path = root.join(GOLDEN);
    if std::env::var_os("VTA_UPDATE_API").is_some() {
        std::fs::create_dir_all(golden_path.parent().unwrap()).unwrap();
        std::fs::write(&golden_path, &current).unwrap();
        println!("regenerated {GOLDEN} ({} entries)", current.lines().count());
        return;
    }
    let golden = std::fs::read_to_string(&golden_path)
        .expect("golden snapshot missing — run with VTA_UPDATE_API=1 to create it");
    if golden != current {
        let golden_set: std::collections::BTreeSet<&str> = golden.lines().collect();
        let current_set: std::collections::BTreeSet<&str> = current.lines().collect();
        let mut diff = String::new();
        for gone in golden_set.difference(&current_set) {
            diff.push_str(&format!("- {gone}\n"));
        }
        for new in current_set.difference(&golden_set) {
            diff.push_str(&format!("+ {new}\n"));
        }
        panic!(
            "public API surface of engine/runtime/sweep changed:\n{diff}\nIf intentional, \
             regenerate with: VTA_UPDATE_API=1 cargo test --test public_api"
        );
    }
}
