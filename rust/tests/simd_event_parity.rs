//! Differential parity for PR 7's two raw-speed mechanisms. Both are
//! pure optimizations with an exactness contract, so the tests are
//! seeded fuzzers comparing the fast path against the always-compiled
//! reference:
//!
//! * **SIMD exec kernels** — `exec::dot_i8` / `exec::alu_tile_imm`
//!   (AVX2/SSE2 under `--features simd`, runtime-detected) must be
//!   bit-identical to `dot_i8_scalar` / `alu_tile_imm_scalar` for every
//!   input. Without the feature the dispatchers *are* the scalar
//!   reference, so the tests pass trivially; CI runs the suite in both
//!   feature configurations so the vector kernels are actually covered.
//!
//! * **Bucketed event wheel** — `Tsim`'s calendar-queue wake scheduling
//!   must reproduce the retired linear wake scan exactly: identical
//!   completion cycles, `ExecCounters`, per-module stall accounting,
//!   scratchpad digests and DRAM output, program for program
//!   (`Tsim::set_linear_scan` keeps the old scan alive for exactly this
//!   comparison).

use vta::compiler::builder::ProgramBuilder;
use vta::compiler::conv::{lower_conv, ConvBases, ConvParams};
use vta::compiler::tps::{self, ConvSpec};
use vta::config::presets;
use vta::config::VtaConfig;
use vta::exec::{alu_tile_imm, alu_tile_imm_scalar, dot_i8, dot_i8_scalar};
use vta::isa::{AluOp, BufferId};
use vta::mem::Dram;
use vta::sim::Tsim;
use vta::util::rng::Pcg32;

// ---------------------------------------------------------------------
// SIMD kernels vs scalar reference
// ---------------------------------------------------------------------

/// Every length from empty through several vector widths past the
/// 16/32-lane blocks, full-range i8 values: the dispatcher must agree
/// with the scalar reference bit for bit (including the worst case,
/// -128 * -128 accumulated across a long vector).
#[test]
fn dot_i8_matches_scalar_reference() {
    let mut rng = Pcg32::seeded(0xd07);
    for len in (0..=96).chain([128, 255, 256, 1000, 1024]) {
        for _ in 0..8 {
            let x = rng.i8_vec_full(len);
            let w = rng.i8_vec_full(len);
            assert_eq!(
                dot_i8(&x, &w),
                dot_i8_scalar(&x, &w),
                "dot_i8 diverged from scalar at len {len}"
            );
        }
    }
    // Saturation-adjacent corner: all lanes at i8::MIN.
    let x = vec![i8::MIN; 256];
    assert_eq!(dot_i8(&x, &x), dot_i8_scalar(&x, &x));
}

/// All ALU ops × tile lengths straddling the 8-lane blocks × random
/// accumulators and immediates. Both variants mutate the accumulator
/// tile in place and narrow into the output tile; both buffers must
/// match element for element.
#[test]
fn alu_tile_imm_matches_scalar_reference() {
    let ops = [
        AluOp::Min,
        AluOp::Max,
        AluOp::Add,
        AluOp::Shr,
        AluOp::Mul,
        AluOp::Clip,
        AluOp::Mov,
    ];
    let mut rng = Pcg32::seeded(0xa1f);
    for &op in &ops {
        for len in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 31, 32, 64, 100] {
            for _ in 0..8 {
                let imm = {
                    let raw = rng.next_u32() as i32;
                    match op {
                        // clamp(-imm, imm) requires a non-negative bound;
                        // negative Clip immediates are rejected upstream.
                        AluOp::Clip => raw & 0x7fff_ffff,
                        // Keep shifts in the interesting window (the
                        // datapath masks to 31 anyway, signed both ways).
                        AluOp::Shr => raw % 64,
                        _ => raw,
                    }
                };
                let acc0: Vec<i32> = (0..len).map(|_| rng.next_u32() as i32).collect();
                let mut acc_fast = acc0.clone();
                let mut acc_ref = acc0;
                let mut out_fast = vec![0i8; len];
                let mut out_ref = vec![0i8; len];
                alu_tile_imm(op, imm, &mut acc_fast, &mut out_fast);
                alu_tile_imm_scalar(op, imm, &mut acc_ref, &mut out_ref);
                assert_eq!(acc_fast, acc_ref, "{op:?} imm={imm} len={len}: acc diverged");
                assert_eq!(out_fast, out_ref, "{op:?} imm={imm} len={len}: out diverged");
            }
        }
    }
}

// ---------------------------------------------------------------------
// Bucketed event wheel vs linear wake scan
// ---------------------------------------------------------------------

/// Everything the timing contract promises, bundled for equality.
#[derive(Debug, PartialEq)]
struct RunFingerprint {
    cycles: u64,
    counters: vta::exec::ExecCounters,
    acc_digest: u64,
    out_digest: u64,
    output: Vec<i8>,
    stalls: [(u64, u64, u64, u64); 3],
    gemm_cycles: u64,
    alu_cycles: u64,
    vme_busy: (u64, u64),
}

/// Lower one conv with seeded input/weights and run it to completion on
/// a fresh `Tsim` in the requested wake-scan mode.
fn run_conv(
    cfg: &VtaConfig,
    spec: ConvSpec,
    seed: u64,
    linear: bool,
    timing_only: bool,
) -> RunFingerprint {
    let oh = (spec.h + 2 * spec.ph - spec.kh) / spec.sh + 1;
    let ow = (spec.w + 2 * spec.pw - spec.kw) / spec.sw + 1;
    let inp_bytes = (spec.c_in / cfg.block_in) * spec.h * spec.w * cfg.inp_tile_bytes();
    let wgt_bytes = (spec.c_out / cfg.block_out)
        * (spec.c_in / cfg.block_in)
        * spec.kh
        * spec.kw
        * cfg.wgt_tile_bytes();
    let out_bytes = (spec.c_out / cfg.block_out) * oh * ow * cfg.out_tile_bytes();
    let mut dram = Dram::new(1 << 22);
    let ri = dram.alloc(inp_bytes, cfg.inp_tile_bytes());
    let rw = dram.alloc(wgt_bytes, cfg.wgt_tile_bytes());
    let ro = dram.alloc(out_bytes, cfg.out_tile_bytes());
    let mut rng = Pcg32::seeded(seed);
    dram.write_i8(ri, &rng.i8_vec(inp_bytes));
    dram.write_i8(rw, &rng.i8_vec(wgt_bytes));
    let tiling = tps::search(&spec, cfg, true);
    let mut b = ProgramBuilder::new(cfg);
    lower_conv(
        &mut b,
        &ConvParams { spec, shift: 4, relu: true },
        &tiling,
        ConvBases {
            inp: ri.tile_base(cfg.inp_tile_bytes()),
            wgt: rw.tile_base(cfg.wgt_tile_bytes()),
            out: ro.tile_base(cfg.out_tile_bytes()),
        },
    );
    let insns = b.finish("wheel-parity", &mut dram).insns;
    let mut sim = if timing_only {
        Tsim::timing_only(cfg)
    } else {
        Tsim::new(cfg)
    };
    sim.set_linear_scan(linear);
    let cycles = sim.run(&insns, &mut dram, "wheel-parity");
    let report = sim.report();
    let stat = |s: &vta::sim::ModuleStats| {
        (s.busy_cycles, s.stall_pop_cycles, s.stall_push_cycles, s.insns)
    };
    RunFingerprint {
        cycles,
        counters: sim.core.counters,
        acc_digest: sim.core.buffer_digest(BufferId::Acc),
        out_digest: sim.core.buffer_digest(BufferId::Out),
        output: dram.read_i8(ro),
        stalls: [stat(&report.load), stat(&report.compute), stat(&report.store)],
        gemm_cycles: report.gemm_cycles,
        alu_cycles: report.alu_cycles,
        vme_busy: (report.vme.read_busy_cycles, report.vme.write_busy_cycles),
    }
}

fn wheel_grid() -> Vec<(VtaConfig, ConvSpec)> {
    let spec_for = |cfg: &VtaConfig, h: usize, k: usize, s: usize| ConvSpec {
        c_in: 2 * cfg.block_in,
        c_out: 2 * cfg.block_out,
        h,
        w: h,
        kh: k,
        kw: k,
        sh: s,
        sw: s,
        ph: k / 2,
        pw: k / 2,
    };
    let mut grid = Vec::new();
    let tiny = presets::tiny_config();
    grid.push((tiny.clone(), spec_for(&tiny, 6, 3, 1)));
    grid.push((tiny.clone(), spec_for(&tiny, 8, 3, 2)));
    grid.push((tiny.clone(), spec_for(&tiny, 5, 1, 1)));
    // Vary the memory system: wide bus + deeper scratchpads change every
    // burst length and wake distance the wheel has to schedule.
    let mut wide = presets::tiny_config();
    wide.name = "tiny-wide".into();
    wide.axi_bytes = 16;
    wide.inp_depth *= 2;
    wide.wgt_depth *= 2;
    wide.acc_depth *= 2;
    grid.push((wide.clone(), spec_for(&wide, 6, 3, 1)));
    let dflt = presets::default_config();
    grid.push((dflt.clone(), spec_for(&dflt, 6, 3, 1)));
    grid
}

/// The wheel is an exact replacement: functional tsim agrees with the
/// linear scan on cycles, counters, stall accounting, scratchpad
/// digests and DRAM output — per config, spec, and input seed.
#[test]
fn bucketed_wheel_matches_linear_scan_functional() {
    for (cfg, spec) in wheel_grid() {
        for seed in [1u64, 2] {
            let wheel = run_conv(&cfg, spec, seed, false, false);
            let linear = run_conv(&cfg, spec, seed, true, false);
            assert_eq!(
                wheel, linear,
                "{}: wheel vs linear scan diverged (functional, seed {seed})",
                cfg.name
            );
        }
    }
}

/// Same contract on the timing-only rung (no functional datapath, so
/// the wake pattern alone determines every number).
#[test]
fn bucketed_wheel_matches_linear_scan_timing_only() {
    for (cfg, spec) in wheel_grid() {
        let wheel = run_conv(&cfg, spec, 7, false, true);
        let linear = run_conv(&cfg, spec, 7, true, true);
        assert_eq!(wheel, linear, "{}: wheel vs linear scan diverged (timing-only)", cfg.name);
    }
}
