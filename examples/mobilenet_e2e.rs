//! End-to-end MobileNet-1.0 (DESIGN.md "e2e-mobilenet"): depthwise
//! convolution executes on the ALU via the new element-wise MUL opcode
//! (§IV-D3), pointwise layers on the GEMM core — the paper's "we are
//! able to execute ... MobileNet network in VTA".
//!
//!     cargo run --release --example mobilenet_e2e [-- --quick]

use vta::config::presets;
use vta::engine::{BackendKind, Engine, EvalRequest};
use vta::util::cli::Args;
use vta::util::rng::Pcg32;
use vta::util::stats;
use vta::workloads;

fn main() {
    let args = Args::parse_from(std::env::args().skip(1));
    let hw = if args.has_flag("quick") { 56 } else { 224 };
    let g = workloads::mobilenet(hw, 2);
    let cfg = presets::default_config();
    let mut rng = Pcg32::seeded(6);
    let input = rng.i8_vec(g.input_shape.elems());
    let expect = g.run_cpu(&input, 1);

    let t = std::time::Instant::now();
    let engine = Engine::for_config(&cfg)
        .backend_kind(BackendKind::Tsim)
        .build()
        .expect("preset configs are valid");
    let eval = engine
        .run(&g, &EvalRequest::with_data(input.clone()))
        .expect("mobilenet is well-formed");
    let out = eval.output.expect("tsim computes tensors");
    assert_eq!(out, expect, "MobileNet output mismatch vs CPU golden");
    println!("MobileNet-1.0 @ {hw}x{hw} on {}: VERIFIED", cfg.tag());

    let mut dw_cycles = 0u64;
    let mut pw_cycles = 0u64;
    for l in &eval.layer_stats {
        match l.kind {
            "depthwise" => dw_cycles += l.cycles,
            "conv" | "dense" => pw_cycles += l.cycles,
            _ => {}
        }
    }
    println!(
        "total {} cycles | depthwise(ALU) {} | conv/dense(GEMM) {} | wall {}",
        eval.cycles.unwrap_or(0),
        stats::si(dw_cycles as f64),
        stats::si(pw_cycles as f64),
        stats::fmt_ns(t.elapsed().as_nanos() as f64)
    );
    println!("output head: {:?}", &out[..8]);
}
