//! End-to-end driver (DESIGN.md "e2e-resnet"): full ResNet-18 inference
//! at 224x224 on the cycle-accurate simulator, per-layer cycle/DRAM
//! breakdown, the paper's headline pipelining comparison, and bit-exact
//! verification of the final logits against the CPU golden model.
//!
//!     cargo run --release --example resnet18_e2e [-- --quick]

use vta::analysis::{area, gantt};
use vta::config::presets;
use vta::engine::{Engine, EvalRequest, TsimBackend};
use vta::util::cli::Args;
use vta::util::rng::Pcg32;
use vta::util::stats;
use vta::workloads;

fn main() {
    let args = Args::parse_from(std::env::args().skip(1));
    let hw = if args.has_flag("quick") { 56 } else { 224 };
    let g = workloads::resnet(18, hw, 1);
    let mut rng = Pcg32::seeded(5);
    let input = rng.i8_vec(g.input_shape.elems());
    let expect = g.run_cpu(&input, 1);

    let mut results = Vec::new();
    for cfg in [presets::original_config(), presets::default_config()] {
        let t = std::time::Instant::now();
        let engine = Engine::for_config(&cfg)
            .backend(TsimBackend::functional())
            .trace(true)
            .build()
            .expect("preset configs are valid");
        let eval = engine
            .run(&g, &EvalRequest::with_data(input.clone()))
            .expect("resnet18 is well-formed");
        let cycles = eval.cycles.expect("tsim measures cycles");
        assert_eq!(
            eval.output.as_deref(),
            Some(&expect[..]),
            "accelerator output mismatch on {}",
            cfg.name
        );
        println!(
            "\n=== {} ({}; scaled area {:.2}) — verified vs golden ===",
            cfg.name,
            cfg.tag(),
            area::scaled_area(&cfg)
        );
        println!("{:<14} {:>12} {:>10} {:>12}", "layer", "cycles", "macs/cyc", "dram rd");
        for l in eval.layer_stats.iter().filter(|l| !l.on_cpu && l.cycles > 0).take(12) {
            println!(
                "{:<14} {:>12} {:>10.1} {:>12}",
                l.name.split(':').next_back().unwrap(),
                l.cycles,
                l.macs as f64 / l.cycles.max(1) as f64,
                l.dram_rd
            );
        }
        println!("  ... ({} layers total)", eval.layer_stats.len());
        let r = eval.report.as_ref().unwrap();
        println!(
            "total: {} cycles | {} MACs | {:.1} MACs/cycle | wall {}",
            cycles,
            stats::si(r.exec.macs as f64),
            r.macs_per_cycle(),
            stats::fmt_ns(t.elapsed().as_nanos() as f64)
        );
        let trace = eval.trace.as_ref().unwrap();
        let u = gantt::utilization(trace, 0, cycles);
        println!(
            "utilization: load {:.0}% | compute {:.0}% (G {:.0}% / A {:.0}%) | store {:.0}%",
            u.load * 100.0,
            u.compute * 100.0,
            u.compute_gemm * 100.0,
            u.compute_alu * 100.0,
            u.store * 100.0
        );
        results.push((cfg.name.clone(), cycles));
    }
    println!(
        "\npipelining speedup: {:.2}x (paper: ~4.9x on the tsim target)",
        results[0].1 as f64 / results[1].1 as f64
    );
}
