//! Design-space exploration (Fig 13) on the parallel sweep engine:
//! shard MAC shape x memory width x scratchpad scaling across worker
//! threads, stream results into a resumable cache, and print the cycle
//! count vs scaled-area Pareto frontier.
//!
//!     cargo run --release --example pareto_sweep [-- --quick]
//!         [--jobs N] [--cache sweep_cache.jsonl --resume]
//!         [--backend tsim|timing|model]
//!         [--two-phase [--prune-epsilon E]]
//!         [--residency off|lru|belady|dtr]
//!
//! Re-running with `--cache f --resume` completes from cache without
//! re-simulating; the frontier is identical for any worker count. With
//! `--two-phase` the analytical model prunes the grid first and tsim
//! runs only on the predicted-front neighborhood — the printed frontier
//! stays 100% tsim-measured. `--backend model` scores the whole grid
//! with the analytical backend instead (instant, unmeasured).

use vta::engine::BackendKind;
use vta::sweep::{self, GridSpec, SweepOptions, TwoPhaseOptions};
use vta::util::cli::Args;

fn main() {
    let args = Args::parse_from(std::env::args().skip(1));
    let spec = GridSpec::fig13(args.has_flag("quick")).to_sweep_spec();
    let resume = args.has_flag("resume");
    // Same data-loss guard as `vta sweep`: without --resume the engine
    // truncates the cache, so refuse to clobber a non-empty one unless
    // --fresh says so.
    if let Some(cache) = args.get("cache") {
        if !resume && !args.has_flag("fresh") {
            if let Ok(meta) = std::fs::metadata(cache) {
                if meta.len() > 0 {
                    eprintln!(
                        "error: cache '{cache}' already holds results; pass --resume to \
                         reuse them or --fresh to discard and start over"
                    );
                    std::process::exit(1);
                }
            }
        }
    }
    // Frontier extraction consumes only cycles/area, so default to the
    // memoized timing-only backend (bit-identical metrics).
    let backend = BackendKind::parse(args.get_or("backend", "timing")).unwrap_or_else(|e| {
        eprintln!("error: {e}");
        std::process::exit(2);
    });
    let opts = SweepOptions {
        jobs: sweep::effective_jobs(args.get_usize("jobs", 0)),
        cache_path: args.get("cache").map(Into::into),
        resume,
        progress: true,
        memo: true,
        backend,
        two_phase: (args.has_flag("two-phase") || args.get("prune-epsilon").is_some()).then(
            || TwoPhaseOptions {
                epsilon: args.get_f64("prune-epsilon", vta::model::DEFAULT_PRUNE_EPSILON),
            },
        ),
        residency: vta::compiler::residency::ResidencyMode::parse(
            args.get_or("residency", "lru"),
        )
        .unwrap_or_else(|| {
            eprintln!("error: unknown residency mode (expected off|lru|belady|dtr)");
            std::process::exit(2);
        }),
    };
    let start = std::time::Instant::now();
    let outcome = sweep::run(&spec, &opts).expect("sweep I/O");
    let rows = &outcome.results;

    println!("\n{} design points; pareto frontier:", rows.len());
    for p in outcome.front.points() {
        let r = &rows[p.id];
        println!("  {:<22} cycles={:<12} area={:.2}", r.config.tag(), r.cycles, r.scaled_area);
    }
    let min_c = rows.iter().map(|r| r.cycles).min().unwrap();
    let max_c = rows.iter().map(|r| r.cycles).max().unwrap();
    let min_a = rows.iter().map(|r| r.scaled_area).fold(f64::MAX, f64::min);
    let max_a = rows.iter().map(|r| r.scaled_area).fold(0.0, f64::max);
    println!(
        "\ncycle span {:.1}x | area span {:.1}x (paper: ~11.5x cycles at ~12x area)",
        max_c as f64 / min_c as f64,
        max_a / min_a
    );
    println!(
        "{} simulated, {} cached, up to {} workers, {:.1}s wall",
        outcome.simulated,
        outcome.cached,
        sweep::effective_jobs(opts.jobs).min(outcome.simulated.max(1)),
        start.elapsed().as_secs_f64()
    );
    if !outcome.pruned.is_empty() {
        println!(
            "two-phase: {} pruned by the analytical model, {:.1}x fewer tsim evaluations",
            outcome.pruned.len(),
            outcome.prune_factor()
        );
    }
}
