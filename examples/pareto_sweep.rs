//! Design-space exploration (Fig 13): sweep MAC shape x memory width x
//! scratchpad scaling, run ResNet-18 on each point, and print the cycle
//! count vs scaled-area Pareto frontier.
//!
//!     cargo run --release --example pareto_sweep [-- --quick]

use vta::repro;
use vta::util::cli::Args;

fn main() {
    let args = Args::parse_from(std::env::args().skip(1));
    let rows = repro::fig13(args.has_flag("quick"));
    println!("\n{} design points; pareto frontier:", rows.len());
    for r in rows.iter().filter(|r| r.pareto) {
        println!("  {:<22} cycles={:<12} area={:.2}", r.config, r.cycles, r.scaled_area);
    }
    let min_c = rows.iter().map(|r| r.cycles).min().unwrap();
    let max_c = rows.iter().map(|r| r.cycles).max().unwrap();
    let min_a = rows.iter().map(|r| r.scaled_area).fold(f64::MAX, f64::min);
    let max_a = rows.iter().map(|r| r.scaled_area).fold(0.0, f64::max);
    println!(
        "\ncycle span {:.1}x | area span {:.1}x (paper: ~11.5x cycles at ~12x area)",
        max_c as f64 / min_c as f64,
        max_a / min_a
    );
}
