//! Quickstart: compile one quantized conv layer, evaluate it through
//! the unified `Engine` API at every rung of the fidelity ladder, and
//! verify the functional rungs bit-for-bit against the CPU reference —
//! and, when `make artifacts` has been run, against the AOT-compiled
//! JAX/Pallas golden model through PJRT.
//!
//!     cargo run --release --example quickstart

use vta::compiler::graph::{Graph, Op};
use vta::compiler::layout::Shape;
use vta::config::presets;
use vta::engine::{BackendKind, Engine, EvalRequest};
use vta::runtime::pjrt::Golden;
use vta::util::rng::Pcg32;

fn main() {
    // The default VTA configuration: 1x16x16 MACs, 64-bit AXI, pipelined.
    let cfg = presets::default_config();
    println!("config: {} ({} MACs/cycle)", cfg.tag(), cfg.macs_per_gemm_op());

    // One 3x3 conv: 16 -> 16 channels over 14x14, stride 1, pad 1,
    // requantized with shift 5 + ReLU (the shapes of the AOT artifact).
    let mut rng = Pcg32::seeded(33);
    let x = rng.i8_vec(16 * 14 * 14);
    let w = rng.i8_vec(16 * 16 * 9);
    let mut g = Graph::new("quickstart", Shape::new(16, 14, 14));
    g.add(
        "conv",
        Op::Conv { c_out: 16, k: 3, stride: 1, pad: 1, shift: 5, relu: true, weights: w.clone() },
        vec![0],
    );
    let expect = g.run_cpu(&x, 1);

    // One engine per fidelity rung; swapping the backend is the only
    // change between a behavioral check, a cycle-accurate measurement,
    // the timing-only fast path, and an instant analytical estimate.
    let mut out = Vec::new();
    for kind in BackendKind::ALL {
        let engine = Engine::for_config(&cfg).backend_kind(kind).build().expect("valid config");
        let eval =
            engine.run(&g, &EvalRequest::with_data(x.clone())).expect("well-formed request");
        let note = if kind == BackendKind::Analytical {
            " (predicted)"
        } else {
            ""
        };
        let cycles = eval
            .cycles
            .map(|c| format!("{c}{note}"))
            .unwrap_or_else(|| "n/a".into());
        println!("{kind:<7} fidelity {:<14} cycles {cycles}", eval.fidelity);
        if let Some(tensor) = eval.output {
            assert_eq!(tensor, expect, "{kind} disagrees with the CPU reference");
            out = tensor;
        }
    }
    println!("cpu reference: MATCH on every output-producing backend ({} int8 values)", out.len());

    // Check against the JAX/Pallas golden model via PJRT (if built).
    let mut golden = Golden::with_default_dir().expect("PJRT client");
    if golden.has_artifact("conv_quickstart") {
        let want = golden
            .run_i8("conv_quickstart", &x, &[1, 16, 14, 14], &w, &[16, 16, 3, 3])
            .expect("golden run");
        assert_eq!(out, want, "simulator disagrees with JAX/Pallas golden");
        println!("pjrt golden:   MATCH (three-layer stack verified)");
    } else {
        println!("pjrt golden:   skipped (run `make artifacts` first)");
    }
}
